//! Kernel-approximation MSE explorer (paper Table 1): how well do the
//! Quadratic, Random Fourier, and Random Maclaurin feature maps
//! approximate the exponential kernel `exp(τ·hᵀc)` on USPS-like
//! normalized data (d = 256)?
//!
//! ```text
//! cargo run --release --example kernel_mse -- --pairs 500
//! ```

use anyhow::Result;
use rfsoftmax::cli::Args;
use rfsoftmax::data::usps_like::{pairs, UspsLikeParams};
use rfsoftmax::featmap::{
    exp_kernel, FeatureMap, MaclaurinMap, QuadraticMap, RffMap,
};
use rfsoftmax::rng::Rng;
use rfsoftmax::tables::{fmt_sci, Table};

/// MSE of a map's exp-kernel estimate over pairs. For RFF the estimator is
/// `e^ν · φ(x)ᵀφ(y)` (eq. 16, normalized embeddings); Quadratic/Maclaurin
/// estimate the kernel directly.
fn mse_for(
    map: &dyn FeatureMap,
    scale: f64,
    tau: f32,
    ps: &[(Vec<f32>, Vec<f32>)],
) -> f64 {
    let mut se = 0.0;
    for (x, y) in ps {
        let e = exp_kernel(tau, x, y) - scale * map.approx_kernel(x, y);
        se += e * e;
    }
    se / ps.len() as f64
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, &["help"])?;
    let n_pairs = a.usize_or("pairs", 300)?;
    let tau = a.f32_or("tau", 1.0)?;
    let d = 256; // USPS dimensionality
    let mut rng = Rng::seeded(a.u64_or("seed", 1)?);
    let ps = pairs(&UspsLikeParams::default(), 512, n_pairs, &mut rng);

    let mut table = Table::new(
        &format!("MSE of approximating exp(τ·hᵀc), τ = {tau}, d = {d} (paper Table 1)"),
        &["Method", "D", "MSE"],
    );

    // Quadratic with least-squares optimal (α, β) — the Table-1 variant.
    let quad = QuadraticMap::fit(d, &ps, |x, y| exp_kernel(tau, x, y));
    table.row(&[
        "Quadratic (fit α,β)".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&quad, 1.0, tau, &ps)),
    ]);
    let quad_fixed = QuadraticMap::new(d, 100.0, 1.0);
    table.row(&[
        "Quadratic (α=100)".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&quad_fixed, 1.0, tau, &ps)),
    ]);

    // Random Fourier at increasing D (ν = τ; scale e^ν).
    let scale = (tau as f64).exp();
    for dd in [100usize, 1000, d * d] {
        let m = RffMap::new(d, dd, tau, &mut rng);
        table.row(&[
            "Random Fourier".into(),
            format!("{dd}"),
            fmt_sci(mse_for(&m, scale, tau, &ps)),
        ]);
    }

    // Random Maclaurin at D = d².
    let mac = MaclaurinMap::new(d, d * d, tau, &mut rng);
    table.row(&[
        "Random Maclaurin".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&mac, 1.0, tau, &ps)),
    ]);

    println!("{}", table.render());
    println!(
        "Expected shape (paper): RFF ≪ Quadratic at equal D; \
         RFF(1000) ≈ 10× better than RFF(100); Maclaurin worst."
    );
    Ok(())
}
