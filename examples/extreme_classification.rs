//! Extreme-classification driver (paper Table 3): train the sparse-input
//! model on a planted multi-label dataset at AmazonCat-13K / Delicious-200K
//! / WikiLSHTC shapes and report PREC@{1,3,5} per sampler.
//!
//! ```text
//! cargo run --release --example extreme_classification -- \
//!     --prefix xc_amazon --samplers exact,uniform,quadratic,rff --steps 400
//! ```

use anyhow::Result;
use rfsoftmax::cli::Args;
use rfsoftmax::config::Config;
use rfsoftmax::coordinator::harness;
use rfsoftmax::coordinator::{Trainer, TrainerBuilder};
use rfsoftmax::runtime::Runtime;
use rfsoftmax::tables::Table;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, &["help"])?;
    if a.has("help") {
        println!(
            "flags: --prefix xc_amazon|xc_delicious|xc_wiki \
             --samplers a,b,c --steps N --dim D --train-size N"
        );
        return Ok(());
    }
    let runtime = Runtime::native();
    let prefix = a.str_or("prefix", "xc_amazon").to_string();
    let samplers = a.str_or("samplers", "exact,uniform,quadratic,rff").to_string();
    println!("platform {} | dataset {prefix}", runtime.platform());

    let mut table = Table::new(
        &format!("PREC@k on {prefix} (paper Table 3 shape)"),
        &["Method", "PREC@1", "PREC@3", "PREC@5", "wall (s)"],
    );

    for s in samplers.split(',') {
        let mut cfg = Config::default();
        // Planted-dataset shape preset (model.kind = extreme + a
        // scale-reduced label space); explicit overrides below win.
        harness::prefix_preset(&mut cfg, &prefix)?;
        cfg.set("sampler.kind", s)?;
        cfg.set("sampler.num_negatives", a.str_or("m", "100"))?;
        cfg.set("sampler.dim", a.str_or("dim", "256"))?;
        cfg.set("sampler.T", a.str_or("T", "0.5"))?;
        cfg.set("train.steps", a.str_or("steps", "2500"))?;
        cfg.set("train.eval_every", a.str_or("steps", "2500"))?;
        cfg.set("train.eval_batches", a.str_or("eval-batches", "8"))?;
        cfg.set("train.lr", a.str_or("lr", "1.0"))?;
        cfg.set("data.train_size", a.str_or("train-size", "12000"))?;
        cfg.set("data.valid_size", a.str_or("test-size", "1024"))?;
        cfg.set("data.noise", a.str_or("noise", "0.15"))?;
        for (k, v) in a.overrides() {
            if k.contains('.') {
                cfg.set(k, v)?;
            }
        }
        println!("\n--- {s} ---");
        let t0 = std::time::Instant::now();
        let mut trainer = TrainerBuilder::new(&runtime, &prefix, cfg).build()?;
        let _report = trainer.run()?;
        let (p1, p3, p5) = match &mut trainer {
            Trainer::Xc(t) => t.final_precisions()?,
            _ => anyhow::bail!("{prefix} is not an XC config"),
        };
        println!("  PREC@1 {p1:.3}  PREC@3 {p3:.3}  PREC@5 {p5:.3}");
        table.row(&[
            s.to_uppercase(),
            format!("{p1:.2}"),
            format!("{p3:.2}"),
            format!("{p5:.2}"),
            format!("{:.1}", t0.elapsed().as_secs_f64()),
        ]);
    }

    println!("\n{}", table.render());
    Ok(())
}
