//! Quickstart: train a tiny language model (n = 1,000 classes) with
//! RF-softmax negative sampling end-to-end on the default **native**
//! backend — fused one-pass train step, no compiled artifacts needed —
//! and compare against uniform sampling.
//!
//! The training loop is **batch-first**: each step maps the whole
//! batch's queries through φ in one gemm, draws its shared negatives
//! with one `SamplerService::draw_batch` call (per-example conditioned
//! probabilities, batch-wide accidental-hit masks) and pushes the step's
//! embedding updates into the sampling tree as one sharded batch. The
//! standalone demo below shows the same `Sampler::sample_batch` API the
//! coordinator uses, without needing compiled artifacts.
//!
//! Run: `cargo run --release --example quickstart`

use rfsoftmax::config::Config;
use rfsoftmax::coordinator::TrainerBuilder;
use rfsoftmax::prelude::*;
use rfsoftmax::runtime::Runtime;

/// Artifact-free demo of the batch sampling API.
fn batch_sampling_demo() {
    let mut rng = Rng::seeded(42);
    let classes = Matrix::randn(&mut rng, 1000, 32).l2_normalized_rows();
    let sampler = RffSampler::new(&classes, 128, 4.0, &mut rng);
    // 8 example queries → one call, 20 negatives each; example b's draw
    // excludes targets[b] and reports exact conditioned probabilities.
    let queries = Matrix::randn(&mut rng, 8, 32).l2_normalized_rows();
    let targets: Vec<u32> = (0..8).collect();
    let batch = sampler.sample_batch(&queries, &targets, 20, &mut rng);
    println!(
        "batch draw: {} examples × {} negatives (q₀₀ = {:.2e})",
        batch.batch(),
        batch.m(),
        batch.draws[0].probs[0]
    );
}

fn main() -> anyhow::Result<()> {
    batch_sampling_demo();

    let runtime = Runtime::native();
    println!("backend: {}", runtime.platform());

    let mut results = Vec::new();
    for sampler in ["rff", "uniform"] {
        let mut cfg = Config::default();
        cfg.set("model.num_classes", "1000")?;
        cfg.set("model.embed_dim", "64")?;
        cfg.set("model.hidden_dim", "96")?;
        cfg.set("model.seq_len", "12")?;
        cfg.set("sampler.kind", sampler)?;
        cfg.set("sampler.num_negatives", "20")?;
        cfg.set("sampler.dim", "128")?;
        cfg.set("sampler.nu", "4.0")?; // T = 1/√ν = 0.5, the paper's pick
        cfg.set("train.steps", "300")?;
        cfg.set("train.eval_every", "75")?;
        cfg.set("train.eval_batches", "8")?;
        cfg.set("train.lr", "0.5")?;
        cfg.set("data.train_size", "30000")?;
        cfg.set("data.valid_size", "3000")?;

        println!("\n=== training with {sampler} sampling ===");
        let mut trainer =
            TrainerBuilder::new(&runtime, "quickstart", cfg).build()?;
        let report = trainer.run()?;
        for p in &report.history {
            println!(
                "  step {:>4} (epoch {:.2}): train loss {:.3}, \
                 valid loss {:.3}, ppl {:.1}",
                p.step, p.epoch, p.train_loss, p.eval_loss, p.metric
            );
        }
        println!(
            "  {} final perplexity: {:.1} ({:.1}s)",
            report.sampler, report.final_metric, report.wall_seconds
        );
        results.push((sampler, report.final_metric));
    }

    println!("\nSummary (lower is better):");
    for (s, ppl) in &results {
        println!("  {s:<8} ppl {ppl:.1}");
    }
    Ok(())
}
