//! Gradient-bias explorer — interactive companion to Theorem 1.
//!
//! Monte-Carlo-estimates `E[∇L′] − ∇L` (logit space) and the eq.-12
//! distribution diagnostics for every sampler, sweeping m.
//!
//! ```text
//! cargo run --release --example bias_explorer -- --n 100 --trials 4000
//! ```

use anyhow::Result;
use rfsoftmax::bias::{empirical_bias, theorem_diagnostics};
use rfsoftmax::cli::Args;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{
    ExactSoftmaxSampler, LogUniformSampler, QuadraticSampler, RffSampler,
    Sampler, UniformSampler,
};
use rfsoftmax::tables::{fmt_sci, Table};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, &["help"])?;
    let n = a.usize_or("n", 100)?;
    let d = a.usize_or("d", 16)?;
    let tau = a.f32_or("tau", 8.0)?;
    let trials = a.usize_or("trials", 4000)?;
    let rff_d = a.usize_or("dim", 1024)?;

    let mut rng = Rng::seeded(a.u64_or("seed", 5)?);
    let mut classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let h = unit_vector(&mut rng, d);
    // Plant a skewed softmax: a few classes near h (the regime where the
    // sampling distribution matters most).
    for i in 0..3.min(n) {
        let row = classes.row_mut(i);
        for (r, &hv) in row.iter_mut().zip(h.iter()) {
            *r = hv + 0.1 * (i as f32 + 1.0);
        }
        rfsoftmax::linalg::l2_normalize(row);
    }
    let target = n / 2;

    let samplers: Vec<(&str, Box<dyn Sampler>)> = vec![
        ("exp", Box::new(ExactSoftmaxSampler::new(&classes, tau))),
        (
            "rff",
            Box::new(RffSampler::new(&classes, rff_d, tau, &mut rng)),
        ),
        (
            "quadratic",
            Box::new(QuadraticSampler::new(&classes, 100.0, 1.0)),
        ),
        ("uniform", Box::new(UniformSampler::new(n))),
        ("loguniform", Box::new(LogUniformSampler::new(n))),
    ];

    for m in [5usize, 20, 100] {
        if m >= n {
            continue;
        }
        let mut table = Table::new(
            &format!(
                "Gradient bias, n={n}, d={d}, τ={tau}, m={m}, {trials} trials \
                 (Theorem 1 empirics)"
            ),
            &["sampler", "|bias|∞", "|bias|₂", "MC-se", "UB₁", "ratio-gap"],
        );
        for (name, s) in &samplers {
            let est = empirical_bias(
                &classes,
                &h,
                target,
                tau,
                s.as_ref(),
                m,
                trials,
                &mut rng,
            );
            let diag = theorem_diagnostics(
                &classes,
                &h,
                target,
                tau,
                s.as_ref(),
                m,
            );
            table.row(&[
                name.to_string(),
                fmt_sci(est.linf),
                fmt_sci(est.l2),
                fmt_sci(est.max_se),
                fmt_sci(diag.ub1),
                fmt_sci(diag.max_ratio_gap / diag.floor.sqrt()),
            ]);
        }
        println!("{}", table.render());
    }
    println!(
        "Expected (Theorem 1): EXP ≈ 0 bias and UB₁ = 0; RFF close to EXP;\n\
         uniform/loguniform clearly worse; all biases shrink as m grows."
    );
    Ok(())
}
