//! Language-model training driver — the workhorse behind Figures 1–4 and
//! the end-to-end validation run recorded in EXPERIMENTS.md.
//!
//! Examples:
//!
//! ```text
//! # RF-softmax vs baselines on the PTB-scale corpus (Figure 3 shape):
//! cargo run --release --example lm_language_model -- \
//!     --prefix ptb --samplers rff,exact,uniform,quadratic,full --steps 600
//!
//! # The paper's ν sweep (Figure 1): T = 1/√ν
//! cargo run --release --example lm_language_model -- \
//!     --prefix ptb --samplers rff --sweep-T 0.3,0.4,0.5,0.7,1.0
//!
//! # End-to-end validation at Bnews scale (~34M parameters):
//! cargo run --release --example lm_language_model -- \
//!     --prefix bnews --samplers rff --steps 400
//! ```

use anyhow::Result;
use rfsoftmax::cli::Args;
use rfsoftmax::config::Config;
use rfsoftmax::coordinator::harness;
use rfsoftmax::coordinator::{TrainerBuilder, TrainReport};
use rfsoftmax::runtime::Runtime;
use rfsoftmax::tables::Table;

fn base_config(a: &Args, prefix: &str) -> Result<Config> {
    let mut cfg = Config::default();
    // Corpus-prefix shape preset for the native backend (the pjrt
    // backend reads shapes from the artifact manifest instead; explicit
    // --section.key overrides below still win).
    harness::prefix_preset(&mut cfg, prefix)?;
    cfg.set("sampler.num_negatives", a.str_or("m", "100"))?;
    cfg.set("sampler.dim", a.str_or("dim", "1024"))?;
    cfg.set("sampler.T", a.str_or("T", "0.5"))?;
    cfg.set("train.steps", a.str_or("steps", "400"))?;
    cfg.set("train.eval_every", a.str_or("eval-every", "100"))?;
    cfg.set("train.eval_batches", a.str_or("eval-batches", "4"))?;
    cfg.set("train.lr", a.str_or("lr", "0.5"))?;
    cfg.set("data.train_size", a.str_or("train-tokens", "120000"))?;
    cfg.set("data.valid_size", a.str_or("valid-tokens", "10000"))?;
    for (k, v) in a.overrides() {
        if k.contains('.') {
            cfg.set(k, v)?;
        }
    }
    Ok(cfg)
}

fn run_one(
    runtime: &Runtime,
    prefix: &str,
    cfg: Config,
    label: &str,
) -> Result<TrainReport> {
    println!("\n--- {label} ---");
    let mut trainer = TrainerBuilder::new(runtime, prefix, cfg).build()?;
    let report = trainer.run()?;
    for p in &report.history {
        println!(
            "  step {:>5} (ep {:.2}) train {:.3} | valid {:.3} | ppl {:.1}",
            p.step, p.epoch, p.train_loss, p.eval_loss, p.metric
        );
    }
    println!(
        "  => {} final ppl {:.2} in {:.1}s",
        report.sampler, report.final_metric, report.wall_seconds
    );
    Ok(report)
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, &["help"])?;
    if a.has("help") {
        println!(
            "flags: --prefix ptb|bnews|quickstart --samplers a,b,c \
             --steps N --m N --dim D --T t --sweep-T t1,t2 --sweep-D d1,d2 \
             --lr x --train-tokens N --csv out.csv \
             (+ any --section.key config override)"
        );
        return Ok(());
    }
    let prefix = a.str_or("prefix", "ptb").to_string();
    // Honors a --train.backend pjrt override; defaults to native.
    let runtime =
        Runtime::for_train(&base_config(&a, &prefix)?, Runtime::default_dir())?;
    println!(
        "platform {} | prefix {prefix} | single-core CPU testbed",
        runtime.platform()
    );

    let mut reports: Vec<(String, TrainReport)> = Vec::new();

    if let Some(ts) = a.get("sweep-T") {
        // Figure 1: vary the RFF kernel temperature T = 1/√ν.
        for t in ts.split(',') {
            let mut cfg = base_config(&a, &prefix)?;
            cfg.set("sampler.kind", "rff")?;
            cfg.set("sampler.T", t)?;
            let r = run_one(&runtime, &prefix, cfg, &format!("rff T={t}"))?;
            reports.push((format!("rff T={t}"), r));
        }
    } else if let Some(ds) = a.get("sweep-D") {
        // Figure 2: vary the RFF dimension D.
        for d in ds.split(',') {
            let mut cfg = base_config(&a, &prefix)?;
            cfg.set("sampler.kind", "rff")?;
            cfg.set("sampler.dim", d)?;
            let r = run_one(&runtime, &prefix, cfg, &format!("rff D={d}"))?;
            reports.push((format!("rff D={d}"), r));
        }
    } else {
        // Figures 3/4: sampler comparison.
        let samplers = a.str_or("samplers", "rff,exact,uniform,quadratic");
        for s in samplers.split(',') {
            let mut cfg = base_config(&a, &prefix)?;
            cfg.set("sampler.kind", s)?;
            let r = run_one(&runtime, &prefix, cfg, s)?;
            reports.push((s.to_string(), r));
        }
    }

    // Summary table (validation perplexity per eval point).
    let steps: Vec<usize> = reports
        .first()
        .map(|(_, r)| r.history.iter().map(|p| p.step).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["step".to_string()];
    header.extend(reports.iter().map(|(n, _)| n.clone()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("Validation perplexity on {prefix} (lower is better)"),
        &header_refs,
    );
    for (row_idx, step) in steps.iter().enumerate() {
        let mut cells = vec![step.to_string()];
        for (_, r) in &reports {
            cells.push(
                r.history
                    .get(row_idx)
                    .map(|p| format!("{:.1}", p.metric))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        table.row(&cells);
    }
    println!("\n{}", table.render());

    if let Some(csv) = a.get("csv") {
        std::fs::write(csv, table.to_csv())?;
        println!("wrote {csv}");
    }
    Ok(())
}
