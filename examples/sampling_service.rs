//! Standalone sampling service — serving-style usage of the library.
//!
//! Runs the RF-softmax kernel tree as a request/response service over a
//! Unix domain socket: clients send a query embedding, the service
//! replies with m sampled class ids + probabilities. Demonstrates the
//! coordinator pieces (worker pool, metrics) outside the training loop —
//! e.g. for retrieval-style "sample candidates ∝ softmax" serving.
//!
//! Protocol (little-endian): request = u32 m | u32 d | f32×d query;
//! response = u32 m | (u32 id, f64 q)×m.
//!
//! ```text
//! cargo run --release --example sampling_service -- --n 50000 --selftest
//! ```

use anyhow::Result;
use rfsoftmax::cli::Args;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::metrics::Metrics;
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{RffSampler, Sampler};
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};

fn handle(
    mut stream: UnixStream,
    sampler: &RffSampler,
    rng: &mut Rng,
    metrics: &mut Metrics,
) -> Result<()> {
    let mut head = [0u8; 8];
    stream.read_exact(&mut head)?;
    let m = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    let d = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; d * 4];
    stream.read_exact(&mut buf)?;
    let query: Vec<f32> = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();

    let draw = metrics.time("sample", || sampler.sample(&query, m, rng));
    metrics.incr("requests", 1);

    let mut out = Vec::with_capacity(4 + m * 12);
    out.extend_from_slice(&(m as u32).to_le_bytes());
    for (id, q) in draw.ids.iter().zip(&draw.probs) {
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&q.to_le_bytes());
    }
    stream.write_all(&out)?;
    Ok(())
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, &["help", "selftest"])?;
    let n = a.usize_or("n", 50_000)?;
    let d = a.usize_or("d", 64)?;
    let dim = a.usize_or("dim", 256)?;
    let nu = a.f32_or("nu", 4.0)?;
    let requests = a.usize_or("requests", 32)?;
    let sock_path = std::env::temp_dir().join(format!("rfsm_sampler_{}.sock", std::process::id()));

    println!("building RF-softmax sampler: n={n} d={d} D={dim} ν={nu} …");
    let mut rng = Rng::seeded(3);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let sampler = RffSampler::new(&classes, dim, nu, &mut rng);
    println!(
        "tree memory: {:.1} MiB",
        sampler.memory_bytes() as f64 / (1 << 20) as f64
    );

    let _ = std::fs::remove_file(&sock_path);
    let listener = UnixListener::bind(&sock_path)?;
    println!("listening on {}", sock_path.display());

    if a.has("selftest") {
        // Spawn a client thread that fires `requests` queries.
        let path = sock_path.clone();
        let client = std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut rng = Rng::seeded(9);
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let q = unit_vector(&mut rng, d);
                let t0 = std::time::Instant::now();
                let mut s = UnixStream::connect(&path)?;
                let m = 10u32;
                s.write_all(&m.to_le_bytes())?;
                s.write_all(&(d as u32).to_le_bytes())?;
                for v in &q {
                    s.write_all(&v.to_le_bytes())?;
                }
                let mut head = [0u8; 4];
                s.read_exact(&mut head)?;
                let got = u32::from_le_bytes(head) as usize;
                let mut body = vec![0u8; got * 12];
                s.read_exact(&mut body)?;
                latencies.push(t0.elapsed().as_secs_f64());
                // Sanity: ids in range, q ∈ (0, 1].
                for chunk in body.chunks_exact(12) {
                    let id =
                        u32::from_le_bytes(chunk[0..4].try_into().unwrap());
                    let qv =
                        f64::from_le_bytes(chunk[4..12].try_into().unwrap());
                    assert!((id as usize) < n);
                    assert!(qv > 0.0 && qv <= 1.0);
                }
            }
            Ok(latencies)
        });

        let mut metrics = Metrics::new();
        let mut served = 0;
        for stream in listener.incoming() {
            handle(stream?, &sampler, &mut rng, &mut metrics)?;
            served += 1;
            if served >= requests {
                break;
            }
        }
        let latencies = client.join().expect("client thread")?;
        let mean = latencies.iter().sum::<f64>() / latencies.len() as f64;
        println!(
            "served {served} requests; client mean round-trip {:.2} ms",
            mean * 1e3
        );
        println!(
            "service-side sample p50 {:?} p95 {:?}",
            metrics.timer("sample").unwrap().quantile(0.5),
            metrics.timer("sample").unwrap().quantile(0.95),
        );
        let _ = std::fs::remove_file(&sock_path);
    } else {
        println!("serving forever (ctrl-c to stop)…");
        let mut metrics = Metrics::new();
        for stream in listener.incoming() {
            handle(stream?, &sampler, &mut rng, &mut metrics)?;
        }
    }
    Ok(())
}
