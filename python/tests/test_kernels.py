"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes and value regimes; tolerances are f32-scale.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rff_map import rff_map
from compile.kernels.sampled_loss import sampled_softmax_loss

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


# ----------------------------------------------------------------------
# rff_map
# ----------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows_mul=st.integers(1, 3),
    d=st.sampled_from([8, 32, 64, 200]),
    freq_mul=st.integers(1, 3),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_rff_map_matches_ref(rows_mul, d, freq_mul, scale):
    # Shapes must tile by the block sizes; the kernel clamps blocks to the
    # array dims, so any multiple of min(128, dim) works.
    rows = 128 * rows_mul
    freqs = 128 * freq_mul
    u = rand(1, (rows, d), scale)
    w = rand(2, (freqs, d), scale)
    got = rff_map(u, w)
    want = ref.rff_map_ref(u, w)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_rff_map_small_shapes():
    # Blocks clamp to small arrays.
    u = rand(3, (16, 8))
    w = rand(4, (32, 8))
    got = rff_map(u, w)
    np.testing.assert_allclose(got, ref.rff_map_ref(u, w), atol=1e-5)


def test_rff_map_norm_is_one():
    # ‖phi‖² = 1 exactly (cos²+sin²).
    u = rand(5, (128, 16))
    w = rand(6, (128, 16))
    phi = rff_map(u, w)
    np.testing.assert_allclose(
        jnp.sum(phi * phi, axis=-1), jnp.ones(128), atol=1e-4
    )


def test_rff_map_unbiased_for_gaussian_kernel():
    # E_w[phi(x)^T phi(y)] = exp(-nu ||x-y||^2 / 2) with w ~ N(0, nu I).
    nu = 2.0
    d = 16
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, d))
    x = x / jnp.linalg.norm(x)
    y = jax.random.normal(jax.random.PRNGKey(8), (1, d))
    y = y / jnp.linalg.norm(y)
    acc = 0.0
    reps = 50
    for r in range(reps):
        w = jnp.sqrt(nu) * jax.random.normal(
            jax.random.PRNGKey(100 + r), (256, d)
        )
        px = rff_map(x, w)
        py = rff_map(y, w)
        acc += float(jnp.sum(px * py))
    est = acc / reps
    exact = float(ref.gaussian_kernel_ref(x[0], y[0], nu))
    assert abs(est - exact) < 0.05, f"{est} vs {exact}"


# ----------------------------------------------------------------------
# sampled_loss
# ----------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b_mul=st.integers(1, 2),
    m=st.sampled_from([1, 7, 20, 100]),
    logit_scale=st.sampled_from([0.5, 3.0, 12.0]),
    with_mask=st.booleans(),
)
def test_sampled_loss_matches_ref(b_mul, m, logit_scale, with_mask):
    b = 128 * b_mul
    tgt = rand(11, (b,), logit_scale)
    neg = rand(12, (b, m), logit_scale)
    adjust = rand(13, (m,), 1.0)
    if with_mask:
        mask = (
            jax.random.uniform(jax.random.PRNGKey(14), (b, m)) > 0.1
        ).astype(jnp.float32)
    else:
        mask = jnp.ones((b, m), jnp.float32)
    got = sampled_softmax_loss(tgt, neg, adjust, mask)
    want = ref.sampled_loss_ref(tgt, neg, adjust, mask)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_sampled_loss_grads_match_ref():
    b, m = 128, 50
    tgt = rand(21, (b,), 2.0)
    neg = rand(22, (b, m), 2.0)
    adjust = rand(23, (m,), 0.5)
    mask = jnp.ones((b, m), jnp.float32)

    def mean_loss(t, n):
        return jnp.mean(sampled_softmax_loss(t, n, adjust, mask))

    def mean_loss_ref(t, n):
        return jnp.mean(ref.sampled_loss_ref(t, n, adjust, mask))

    g = jax.grad(mean_loss, argnums=(0, 1))(tgt, neg)
    gr = jax.grad(mean_loss_ref, argnums=(0, 1))(tgt, neg)
    np.testing.assert_allclose(g[0], gr[0], atol=1e-5)
    np.testing.assert_allclose(g[1], gr[1], atol=1e-5)


def test_sampled_loss_grad_vs_finite_difference():
    b, m = 128, 5
    tgt = rand(31, (b,), 1.0)
    neg = rand(32, (b, m), 1.0)
    adjust = jnp.zeros((m,))
    mask = jnp.ones((b, m), jnp.float32)

    def f(t):
        return jnp.mean(sampled_softmax_loss(t, neg, adjust, mask))

    g = jax.grad(f)(tgt)
    eps = 1e-3
    e0 = jnp.zeros_like(tgt).at[0].set(eps)
    fd = (f(tgt + e0) - f(tgt - e0)) / (2 * eps)
    assert abs(float(fd - g[0])) < 1e-3


def test_sampled_loss_stability_large_logits():
    b, m = 128, 10
    tgt = jnp.full((b,), 500.0)
    neg = jnp.full((b, m), 499.0)
    adjust = jnp.zeros((m,))
    mask = jnp.ones((b, m), jnp.float32)
    loss = sampled_softmax_loss(tgt, neg, adjust, mask)
    assert bool(jnp.all(jnp.isfinite(loss)))


def test_mask_drops_entries():
    # Masking every negative leaves loss = logsumexp([o_t]) - o_t = 0.
    b, m = 128, 4
    tgt = rand(41, (b,), 1.0)
    neg = rand(42, (b, m), 1.0)
    adjust = jnp.zeros((m,))
    mask = jnp.zeros((b, m), jnp.float32)
    loss = sampled_softmax_loss(tgt, neg, adjust, mask)
    np.testing.assert_allclose(loss, jnp.zeros(b), atol=1e-5)


def test_adjustment_shifts_partition():
    # Uniform q = 1/n with n = m makes adjustment log(m/m)=0 a no-op;
    # doubling q (adjust += ln 2) must lower each negative's weight.
    b, m = 128, 8
    tgt = rand(51, (b,), 1.0)
    neg = rand(52, (b, m), 1.0)
    mask = jnp.ones((b, m), jnp.float32)
    l0 = sampled_softmax_loss(tgt, neg, jnp.zeros((m,)), mask)
    l1 = sampled_softmax_loss(
        tgt, neg, jnp.full((m,), float(np.log(2.0))), mask
    )
    assert bool(jnp.all(l1 <= l0 + 1e-6))
