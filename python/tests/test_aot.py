"""AOT pipeline tests: manifest completeness, HLO-text validity, shape
agreement between the manifest and the lowered computations."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Build only the tiny config to keep the test fast.
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out), "--configs", "quickstart,rff_map"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    return out


def load_manifest(out):
    with open(os.path.join(out, "manifest.json")) as f:
        return json.load(f)


def test_manifest_exists_and_complete(built):
    m = load_manifest(built)
    names = set(m["artifacts"])
    assert {
        "rff_map",
        "quickstart_encode",
        "quickstart_train_sampled",
        "quickstart_train_sampled_abs",
        "quickstart_train_full",
        "quickstart_eval",
    } <= names


def test_hlo_files_exist_and_are_text(built):
    m = load_manifest(built)
    for name, meta in m["artifacts"].items():
        path = os.path.join(built, meta["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name}: not HLO text"


def test_manifest_shapes_match_eval_shape(built):
    m = load_manifest(built)
    a = m["artifacts"]["quickstart_train_sampled"]
    cfg = aot.LM_CONFIGS["quickstart"]
    by_name = {t["name"]: t for t in a["inputs"]}
    assert by_name["ctx_emb"]["shape"] == [
        cfg["batch"], cfg["seq_len"], cfg["d"],
    ]
    assert by_name["neg_emb"]["shape"] == [cfg["m"], cfg["d"]]
    assert by_name["neg_mask"]["shape"] == [cfg["batch"], cfg["m"]]
    outs = {t["name"]: t for t in a["outputs"]}
    assert outs["loss"]["shape"] == []
    assert outs["d_ctx_emb"]["shape"] == by_name["ctx_emb"]["shape"]
    assert outs["d_neg_emb"]["shape"] == by_name["neg_emb"]["shape"]


def test_meta_carries_model_dims(built):
    m = load_manifest(built)
    meta = m["artifacts"]["quickstart_train_sampled"]["meta"]
    for k in ("kind", "n", "d", "hidden", "seq_len", "batch", "m", "tau"):
        assert k in meta, k
    assert meta["kind"] == "lm"


def test_int_inputs_marked_i32(built):
    m = load_manifest(built)
    a = m["artifacts"]["quickstart_eval"]
    by_name = {t["name"]: t for t in a["inputs"]}
    assert by_name["targets"]["dtype"] == "i32"
