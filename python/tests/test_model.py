"""L2 model entry points: shapes, gradients, and loss semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B, L, D, H, M, N, NNZ = 16, 8, 32, 64, 20, 100, 6
TAU = 1.0 / 0.09


def key(i):
    return jax.random.PRNGKey(i)


@pytest.fixture(scope="module")
def lm_inputs():
    return dict(
        ctx_emb=0.1 * jax.random.normal(key(1), (B, L, D)),
        wx=0.05 * jax.random.normal(key(2), (D, 4 * H)),
        wh=0.05 * jax.random.normal(key(3), (H, 4 * H)),
        b=jnp.zeros((4 * H,)),
        proj=0.1 * jax.random.normal(key(4), (H, D)),
    )


def test_lm_encode_is_normalized(lm_inputs):
    (h,) = model.lm_encode_entry(**lm_inputs)
    assert h.shape == (B, D)
    np.testing.assert_allclose(
        jnp.linalg.norm(h, axis=-1), jnp.ones(B), atol=1e-5
    )


def test_lm_train_sampled_shapes_and_grads(lm_inputs):
    tgt = jax.random.normal(key(5), (B, D))
    neg = jax.random.normal(key(6), (M, D))
    adjust = jnp.zeros((M,))
    mask = jnp.ones((B, M))
    out = model.lm_train_sampled_entry(
        *lm_inputs.values(), tgt, neg, adjust, mask, tau=TAU
    )
    loss, d_ctx, d_wx, d_wh, d_b, d_proj, d_tgt, d_neg = out
    assert loss.shape == ()
    assert float(loss) > 0
    assert d_ctx.shape == (B, L, D)
    assert d_wx.shape == (D, 4 * H)
    assert d_wh.shape == (H, 4 * H)
    assert d_b.shape == (4 * H,)
    assert d_proj.shape == (H, D)
    assert d_tgt.shape == (B, D)
    assert d_neg.shape == (M, D)
    # Target gradient should pull h toward the target: for normalized
    # embeddings, d_tgt must be non-zero.
    assert float(jnp.max(jnp.abs(d_tgt))) > 0


def test_lm_full_loss_close_to_sampled_with_exhaustive_negatives(lm_inputs):
    """Sampled loss with ALL negatives at exact-uniform q == full loss."""
    n_small = M + 1  # target + M negatives covers the whole class set
    cls = jax.random.normal(key(7), (n_small, D))
    targets = jnp.zeros((B,), jnp.int32)  # class 0 for everyone
    out_full = model.lm_train_full_entry(
        *lm_inputs.values(), cls, targets, tau=TAU
    )
    loss_full = out_full[0]

    # Negatives = classes 1..M with q = 1/M each.
    tgt_emb = jnp.broadcast_to(cls[0], (B, D))
    neg_emb = cls[1:]
    adjust = jnp.log(jnp.full((M,), M * (1.0 / M)))
    mask = jnp.ones((B, M))
    out_sampled = model.lm_train_sampled_entry(
        *lm_inputs.values(), tgt_emb, neg_emb, adjust, mask, tau=TAU
    )
    loss_sampled = out_sampled[0]
    np.testing.assert_allclose(
        float(loss_full), float(loss_sampled), rtol=1e-5
    )


def test_lm_eval_matches_train_full_loss(lm_inputs):
    cls = jax.random.normal(key(8), (N, D))
    targets = jnp.arange(B, dtype=jnp.int32)
    (loss_eval,) = model.lm_eval_entry(
        *lm_inputs.values(), cls, targets, tau=TAU
    )
    out_full = model.lm_train_full_entry(
        *lm_inputs.values(), cls, targets, tau=TAU
    )
    np.testing.assert_allclose(
        float(loss_eval), float(out_full[0]), rtol=1e-6
    )


def test_absolute_variant_differs(lm_inputs):
    cls = jax.random.normal(key(9), (N, D))
    targets = jnp.arange(B, dtype=jnp.int32)
    normal = model.lm_train_full_entry(
        *lm_inputs.values(), cls, targets, tau=TAU, absolute=False
    )[0]
    absolute = model.lm_train_full_entry(
        *lm_inputs.values(), cls, targets, tau=TAU, absolute=True
    )[0]
    assert abs(float(normal) - float(absolute)) > 1e-6


def test_unnormalized_variant_differs(lm_inputs):
    cls = jax.random.normal(key(10), (N, D))
    targets = jnp.arange(B, dtype=jnp.int32)
    norm = model.lm_eval_entry(
        *lm_inputs.values(), cls, targets, tau=TAU, normalize=True
    )[0]
    unnorm = model.lm_eval_entry(
        *lm_inputs.values(), cls, targets, tau=TAU, normalize=False
    )[0]
    assert abs(float(norm) - float(unnorm)) > 1e-6


# ----------------------------------------------------------------------
# XC model
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def xc_inputs():
    return dict(
        feat_emb=0.2 * jax.random.normal(key(11), (B, NNZ, D)),
        vals=jnp.ones((B, NNZ)),
    )


def test_xc_h_is_normalized(xc_inputs):
    h = model.xc_h(**xc_inputs)
    np.testing.assert_allclose(
        jnp.linalg.norm(h, axis=-1), jnp.ones(B), atol=1e-5
    )


def test_xc_train_sampled_shapes(xc_inputs):
    tgt = jax.random.normal(key(12), (B, D))
    neg = jax.random.normal(key(13), (M, D))
    out = model.xc_train_sampled_entry(
        xc_inputs["feat_emb"], xc_inputs["vals"], tgt, neg,
        jnp.zeros((M,)), jnp.ones((B, M)), tau=TAU,
    )
    loss, d_feat, d_tgt, d_neg = out
    assert loss.shape == ()
    assert d_feat.shape == (B, NNZ, D)
    assert d_tgt.shape == (B, D)
    assert d_neg.shape == (M, D)


def test_xc_scores_shape_and_ordering(xc_inputs):
    cls = jax.random.normal(key(14), (N, D))
    (scores,) = model.xc_scores_entry(
        xc_inputs["feat_emb"], xc_inputs["vals"], cls, tau=TAU
    )
    assert scores.shape == (B, N)
    # Scores must equal tau * <h, normalized class>.
    h = model.xc_h(**xc_inputs)
    c = cls / jnp.linalg.norm(cls, axis=-1, keepdims=True)
    np.testing.assert_allclose(scores, TAU * h @ c.T, rtol=1e-4, atol=1e-4)


def test_xc_full_gradient_rows_are_sparse_for_targets(xc_inputs):
    # Classes never appearing as the target still receive gradient through
    # the partition function, but the target rows must dominate.
    cls = 0.1 * jax.random.normal(key(15), (N, D))
    targets = jnp.zeros((B,), jnp.int32)
    out = model.xc_train_full_entry(
        xc_inputs["feat_emb"], xc_inputs["vals"], cls, targets, tau=TAU
    )
    d_cls = out[2]
    row_norms = jnp.linalg.norm(d_cls, axis=-1)
    assert float(row_norms[0]) == pytest.approx(
        float(jnp.max(row_norms)), rel=1e-3
    )
