"""L2: the paper's models in JAX, calling the L1 Pallas kernels.

Two model families (paper section 4.1):

* LM — context tokens -> (gathered) input embeddings -> LSTM -> projection
  -> L2-normalized h; sampled softmax against target + shared negatives.
* XC (extreme classification) — sparse features -> (gathered) feature
  embeddings -> weighted sum -> L2-normalized h; same loss.

Every entry point is a *pure function of explicit tensors* — the Rust
coordinator owns all state, performs the embedding gathers/scatters, and
passes parameters each call (DESIGN.md section 1). Gradients are returned
for every trainable input.

Logit conventions (paper eq. 1, 5):
  o_i = tau * h^T c_i with h, c normalized (when `normalize`);
  sampled negatives arrive with `adjust = log(m q)` and an accidental-hit
  mask; the Quadratic baseline's absolute-softmax variant uses |o|.
"""

import jax
import jax.numpy as jnp

from .kernels.sampled_loss import sampled_softmax_loss

EPS = 1e-6


def l2_normalize(x, axis=-1):
    n = jnp.sqrt(jnp.sum(x * x, axis=axis, keepdims=True))
    return x / jnp.maximum(n, EPS)


# ----------------------------------------------------------------------
# Encoders
# ----------------------------------------------------------------------


def lstm_encode(ctx_emb, wx, wh, b):
    """Single-layer LSTM over the context window; returns the final h.

    ctx_emb: (B, L, d); wx: (d, 4H); wh: (H, 4H); b: (4H,).
    Gate order: i, f, g, o (matches the Rust forget-bias init).
    """
    bsz = ctx_emb.shape[0]
    hidden = wh.shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ wx + h @ wh + b  # (B, 4H)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i = jax.nn.sigmoid(i)
        f = jax.nn.sigmoid(f)
        g = jnp.tanh(g)
        o = jax.nn.sigmoid(o)
        c = f * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), None

    xs = jnp.transpose(ctx_emb, (1, 0, 2))  # (L, B, d)
    init = (
        jnp.zeros((bsz, hidden), ctx_emb.dtype),
        jnp.zeros((bsz, hidden), ctx_emb.dtype),
    )
    (h, _), _ = jax.lax.scan(step, init, xs)
    return h  # (B, H)


def lm_h(ctx_emb, wx, wh, b, proj, *, normalize=True):
    """LM input embedding h (B, d)."""
    h = lstm_encode(ctx_emb, wx, wh, b) @ proj  # (B, d)
    return l2_normalize(h) if normalize else h


def xc_h(feat_emb, vals, *, normalize=True):
    """XC input embedding: value-weighted feature-embedding sum (B, d)."""
    h = jnp.sum(vals[..., None] * feat_emb, axis=1)
    return l2_normalize(h) if normalize else h


# ----------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------


def _sampled_loss_from_h(h, tgt_emb, neg_emb, adjust, mask, *, tau,
                         normalize, absolute):
    """Mean sampled-softmax loss given the input embedding h."""
    tgt = l2_normalize(tgt_emb) if normalize else tgt_emb
    neg = l2_normalize(neg_emb) if normalize else neg_emb
    o_t = tau * jnp.sum(h * tgt, axis=-1)  # (B,)
    o_n = tau * (h @ neg.T)  # (B, m)
    if absolute:
        o_t = jnp.abs(o_t)
        o_n = jnp.abs(o_n)
    per_example = sampled_softmax_loss(o_t, o_n, adjust, mask)
    return jnp.mean(per_example)


def _full_loss_from_h(h, cls, targets, *, tau, normalize, absolute):
    """Mean full-softmax cross-entropy (paper eq. 3)."""
    c = l2_normalize(cls) if normalize else cls
    o = tau * (h @ c.T)  # (B, n)
    if absolute:
        o = jnp.abs(o)
    o_t = jnp.take_along_axis(o, targets[:, None], axis=1)[:, 0]
    lse = jax.scipy.special.logsumexp(o, axis=1)
    return jnp.mean(lse - o_t)


# ----------------------------------------------------------------------
# LM entry points (each returns a tuple: loss first, then gradients)
# ----------------------------------------------------------------------


def lm_encode_entry(ctx_emb, wx, wh, b, proj, *, normalize=True):
    return (lm_h(ctx_emb, wx, wh, b, proj, normalize=normalize),)


def lm_train_sampled_entry(ctx_emb, wx, wh, b, proj, tgt_emb, neg_emb,
                           adjust, mask, *, tau, normalize=True,
                           absolute=False):
    def loss_fn(ctx_emb, wx, wh, b, proj, tgt_emb, neg_emb):
        h = lm_h(ctx_emb, wx, wh, b, proj, normalize=normalize)
        return _sampled_loss_from_h(
            h, tgt_emb, neg_emb, adjust, mask,
            tau=tau, normalize=normalize, absolute=absolute,
        )

    loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(7)))(
        ctx_emb, wx, wh, b, proj, tgt_emb, neg_emb
    )
    return (loss, *grads)


def lm_train_full_entry(ctx_emb, wx, wh, b, proj, cls, targets, *, tau,
                        normalize=True, absolute=False):
    def loss_fn(ctx_emb, wx, wh, b, proj, cls):
        h = lm_h(ctx_emb, wx, wh, b, proj, normalize=normalize)
        return _full_loss_from_h(
            h, cls, targets, tau=tau, normalize=normalize, absolute=absolute
        )

    loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(6)))(
        ctx_emb, wx, wh, b, proj, cls
    )
    return (loss, *grads)


def lm_eval_entry(ctx_emb, wx, wh, b, proj, cls, targets, *, tau,
                  normalize=True):
    h = lm_h(ctx_emb, wx, wh, b, proj, normalize=normalize)
    return (
        _full_loss_from_h(
            h, cls, targets, tau=tau, normalize=normalize, absolute=False
        ),
    )


# ----------------------------------------------------------------------
# XC entry points
# ----------------------------------------------------------------------


def xc_train_sampled_entry(feat_emb, vals, tgt_emb, neg_emb, adjust, mask,
                           *, tau, normalize=True, absolute=False):
    def loss_fn(feat_emb, tgt_emb, neg_emb):
        h = xc_h(feat_emb, vals, normalize=normalize)
        return _sampled_loss_from_h(
            h, tgt_emb, neg_emb, adjust, mask,
            tau=tau, normalize=normalize, absolute=absolute,
        )

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
        feat_emb, tgt_emb, neg_emb
    )
    return (loss, *grads)


def xc_train_full_entry(feat_emb, vals, cls, targets, *, tau,
                        normalize=True, absolute=False):
    def loss_fn(feat_emb, cls):
        h = xc_h(feat_emb, vals, normalize=normalize)
        return _full_loss_from_h(
            h, cls, targets, tau=tau, normalize=normalize, absolute=absolute
        )

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(feat_emb, cls)
    return (loss, *grads)


def xc_scores_entry(feat_emb, vals, cls, *, tau, normalize=True):
    h = xc_h(feat_emb, vals, normalize=normalize)
    c = l2_normalize(cls) if normalize else cls
    return (tau * (h @ c.T),)
