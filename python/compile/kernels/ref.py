"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

These are the CORE correctness signal: every Pallas kernel must match its
oracle to float tolerance under hypothesis-driven shape/value sweeps
(python/tests/test_kernels.py).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def rff_map_ref(u, w):
    """Reference RFF feature map (paper eq. 17).

    Args:
      u: (B, d) input vectors.
      w: (D, d) frequency matrix, rows ~ N(0, nu*I).

    Returns:
      (B, 2D): [cos(u @ w.T) | sin(u @ w.T)] / sqrt(D).
    """
    proj = u @ w.T  # (B, D)
    d_feat = w.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_feat, dtype=u.dtype))
    return jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1) * scale


def sampled_loss_ref(tgt_logit, neg_logits, adjust, mask):
    """Reference sampled-softmax loss (paper eq. 5-6), per example.

    Args:
      tgt_logit: (B,) target logits o_t.
      neg_logits: (B, m) sampled-negative logits o_{s_i}.
      adjust: (m,) log(m * q_i) adjustments.
      mask: (B, m) accidental-hit mask; 0 entries are dropped (-inf logit).

    Returns:
      (B,) per-example loss: logsumexp([o_t, o' ...]) - o_t.
    """
    adj = neg_logits - adjust[None, :]
    adj = jnp.where(mask > 0, adj, NEG_INF)
    full = jnp.concatenate([tgt_logit[:, None], adj], axis=1)  # (B, m+1)
    mx = jnp.max(full, axis=1, keepdims=True)
    lse = jnp.squeeze(mx, 1) + jnp.log(
        jnp.sum(jnp.exp(full - mx), axis=1)
    )
    return lse - tgt_logit


def sampled_loss_grads_ref(tgt_logit, neg_logits, adjust, mask):
    """Gradients of `sampled_loss_ref` w.r.t. (tgt_logit, neg_logits)."""
    adj = neg_logits - adjust[None, :]
    adj = jnp.where(mask > 0, adj, NEG_INF)
    full = jnp.concatenate([tgt_logit[:, None], adj], axis=1)
    p = jnp.exp(full - jnp.max(full, axis=1, keepdims=True))
    p = p / jnp.sum(p, axis=1, keepdims=True)
    d_tgt = p[:, 0] - 1.0
    d_neg = p[:, 1:]
    return d_tgt, d_neg


def gaussian_kernel_ref(x, y, nu):
    """exp(-nu * ||x - y||^2 / 2)."""
    d2 = jnp.sum((x - y) ** 2, axis=-1)
    return jnp.exp(-nu * d2 / 2.0)


def exp_kernel_ref(x, y, tau):
    """exp(tau * x . y)."""
    return jnp.exp(tau * jnp.sum(x * y, axis=-1))
