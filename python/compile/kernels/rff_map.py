"""L1 Pallas kernel: the Random Fourier Feature map (paper eq. 17).

phi(u) = sqrt(1/D) * [cos(W u) | sin(W u)],  W in R^{D x d}

TPU mapping (DESIGN.md section Hardware-Adaptation): the u @ W^T core is an
MXU matmul tiled (BM x d) x (d x BD); cos/sin are VPU element-wise ops on
the VMEM-resident accumulator tile. The grid expresses the HBM->VMEM
schedule a CUDA implementation would write with threadblocks + shared
memory. `interpret=True` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO for this image and
serves as the compile-only TPU artifact otherwise.

VMEM footprint per grid step (f32): BM*d + BD*d + 2*BM*BD floats.
With BM=BD=128, d<=512: 128*512*2*4B = 512 KiB + 128*128*2*4B = 128 KiB
~ 0.6 MiB << 16 MiB VMEM, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles (128 x 128 systolic array).
BLOCK_ROWS = 128
BLOCK_FEATS = 128


def _rff_kernel(u_ref, w_ref, cos_ref, sin_ref, *, inv_sqrt_d):
    """One (row-block, feature-block) grid step."""
    u = u_ref[...]  # (bm, d)
    w = w_ref[...]  # (bd, d)
    # MXU: (bm, d) @ (d, bd).
    proj = jnp.dot(u, w.T, preferred_element_type=jnp.float32)
    cos_ref[...] = jnp.cos(proj) * inv_sqrt_d
    sin_ref[...] = jnp.sin(proj) * inv_sqrt_d


def rff_map(u, w, *, block_rows=BLOCK_ROWS, block_feats=BLOCK_FEATS):
    """Pallas RFF map: returns (B, 2D) features [cos | sin] / sqrt(D).

    Shapes must tile evenly for the BlockSpec grid; callers pad. (aot.py
    only emits configs whose shapes tile.)
    """
    b, d = u.shape
    d_feat = w.shape[0]
    assert w.shape[1] == d, f"w dim mismatch: {w.shape} vs d={d}"
    bm = min(block_rows, b)
    bd = min(block_feats, d_feat)
    assert b % bm == 0, f"rows {b} must tile by {bm}"
    assert d_feat % bd == 0, f"features {d_feat} must tile by {bd}"
    inv_sqrt_d = 1.0 / (d_feat**0.5)
    grid = (b // bm, d_feat // bd)
    cos, sin = pl.pallas_call(
        functools.partial(_rff_kernel, inv_sqrt_d=inv_sqrt_d),
        grid=grid,
        in_specs=[
            # u: one row-block, full d (weights stream over j).
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            # w: one feature-block, full d.
            pl.BlockSpec((bd, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d_feat), jnp.float32),
            jax.ShapeDtypeStruct((b, d_feat), jnp.float32),
        ],
        interpret=True,
    )(u, w)
    return jnp.concatenate([cos, sin], axis=-1)
