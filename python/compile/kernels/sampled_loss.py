"""L1 Pallas kernel: fused sampled-softmax loss (paper eq. 5-6).

Per example row: adjusted logits o' = o - log(m q) with the accidental-hit
mask pushing collisions to -inf, then a numerically-stable
logsumexp([o_t, o'...]) - o_t, all in one VMEM-resident pass (no HBM
round-trip for the (B, m) logit block).

Autodiff: pallas_call has no VJP rule, so the public entry
`sampled_softmax_loss` wraps the kernel in jax.custom_vjp with the
analytic backward (p' - e_t), which is what the L2 train-step graphs
differentiate through. The backward is plain jnp (cheap relative to the
model's LSTM/matmul backward).

TPU mapping: grid over row-blocks; one (BM, m) tile + (BM,) target column
live in VMEM; reductions are VPU ops along lanes. VMEM per step:
BM*(m+3) floats -> 128*103*4B ~ 53 KiB at m=100.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_ROWS = 128
NEG_INF = ref.NEG_INF


def _loss_kernel(tgt_ref, neg_ref, adj_ref, mask_ref, out_ref):
    tgt = tgt_ref[...]  # (bm,)
    neg = neg_ref[...]  # (bm, m)
    adjust = adj_ref[...]  # (m,)
    mask = mask_ref[...]  # (bm, m)
    o_adj = neg - adjust[None, :]
    o_adj = jnp.where(mask > 0, o_adj, NEG_INF)
    # Stable logsumexp over [tgt | o_adj] without materializing the concat:
    row_max = jnp.maximum(jnp.max(o_adj, axis=1), tgt)  # (bm,)
    sumexp = jnp.exp(tgt - row_max) + jnp.sum(
        jnp.exp(o_adj - row_max[:, None]), axis=1
    )
    out_ref[...] = row_max + jnp.log(sumexp) - tgt


def _loss_fwd_pallas(tgt_logit, neg_logits, adjust, mask, *, block_rows=BLOCK_ROWS):
    b, m = neg_logits.shape
    bm = min(block_rows, b)
    assert b % bm == 0, f"batch {b} must tile by {bm}"
    grid = (b // bm,)
    return pl.pallas_call(
        _loss_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((bm, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(tgt_logit, neg_logits, adjust, mask)


@jax.custom_vjp
def sampled_softmax_loss(tgt_logit, neg_logits, adjust, mask):
    """Per-example sampled-softmax loss, fused Pallas forward."""
    return _loss_fwd_pallas(tgt_logit, neg_logits, adjust, mask)


def _fwd(tgt_logit, neg_logits, adjust, mask):
    loss = _loss_fwd_pallas(tgt_logit, neg_logits, adjust, mask)
    return loss, (tgt_logit, neg_logits, adjust, mask)


def _bwd(res, g):
    tgt_logit, neg_logits, adjust, mask = res
    d_tgt, d_neg = ref.sampled_loss_grads_ref(
        tgt_logit, neg_logits, adjust, mask
    )
    return (g * d_tgt, g[:, None] * d_neg, None, None)


sampled_softmax_loss.defvjp(_fwd, _bwd)
