"""AOT pipeline: lower every L2 entry point to HLO TEXT + manifest.json.

Usage (normally via `make artifacts`):

    cd python && python -m compile.aot --out ../artifacts [--configs ptb,...]

HLO *text* (not `.serialize()`) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction
ids); the text parser reassigns ids (see /opt/xla-example/README.md).

The manifest records every entry point's input/output names, dtypes and
shapes plus the generating config, so the Rust coordinator discovers model
shapes from the manifest instead of trusting its own config (no drift).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ----------------------------------------------------------------------
# Experiment configs (shapes baked into the artifacts).
# tau = 1 / 0.3^2: the paper's best FULL temperature (section 4.1).
# ----------------------------------------------------------------------

TAU = 1.0 / (0.3 * 0.3)

LM_CONFIGS = {
    # Tiny end-to-end config for tests + quickstart example.
    "quickstart": dict(n=1000, d=32, hidden=64, seq_len=8, batch=16, m=20,
                       tau=TAU),
    # PennTreeBank-scale (paper: n=10,000, d=200; hidden/seq scaled for
    # CPU wall-time, see DESIGN.md section 2).
    "ptb": dict(n=10_000, d=100, hidden=128, seq_len=10, batch=64, m=100,
                tau=TAU),
    # Bnews-scale (paper: n=64,000, d=512 -> d=256 CPU-scaled).
    "bnews": dict(n=64_000, d=256, hidden=256, seq_len=10, batch=64, m=100,
                  tau=TAU),
}

XC_CONFIGS = {
    # AmazonCat-13K: n=13,330, v=203,882, d=128 (paper table 3).
    "xc_amazon": dict(n=13_330, v=203_882, d=128, nnz=16, batch=32, m=100,
                      tau=TAU),
    # Delicious-200K: n=205,443, v=782,585.
    "xc_delicious": dict(n=205_443, v=782_585, d=128, nnz=16, batch=32,
                         m=100, tau=TAU),
    # WikiLSHTC-325K: n=325,056, v=1,617,899.
    "xc_wiki": dict(n=325_056, v=1_617_899, d=128, nnz=16, batch=32, m=100,
                    tau=TAU),
}

# Standalone RFF feature-map artifact (bulk phi computation; also the
# direct L1-kernel smoke artifact for the Rust integration tests).
RFF_MAP_CONFIG = dict(rows=512, d=128, num_freqs=256)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def tensor_meta(name, s):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[s.dtype]
    return {"name": name, "dtype": dt, "shape": list(s.shape)}


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.artifacts = {}

    def emit(self, name, fn, inputs, output_names, meta):
        """Lower `fn` at `inputs` [(name, spec)...] and write HLO text."""
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        # Output shapes from the jitted abstract eval.
        out = jax.eval_shape(fn, *specs)
        assert len(out) == len(output_names), (
            f"{name}: {len(out)} outputs vs {len(output_names)} names"
        )
        self.artifacts[name] = {
            "file": fname,
            "inputs": [tensor_meta(n, s) for n, s in inputs],
            "outputs": [
                tensor_meta(n, s) for n, s in zip(output_names, out)
            ],
            "meta": meta,
        }
        print(f"  {name:<30} {len(text):>9} chars")

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "artifacts": self.artifacts}, f,
                      indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.artifacts)} artifacts)")


def emit_lm(em, prefix, cfg, *, full=True, unnorm=False):
    n, d, hidden = cfg["n"], cfg["d"], cfg["hidden"]
    seq_len, batch, m, tau = (
        cfg["seq_len"], cfg["batch"], cfg["m"], cfg["tau"],
    )
    meta = {"kind": "lm", **cfg}
    ctx = ("ctx_emb", spec([batch, seq_len, d]))
    wx = ("wx", spec([d, 4 * hidden]))
    wh = ("wh", spec([hidden, 4 * hidden]))
    b = ("b", spec([4 * hidden]))
    proj = ("proj", spec([hidden, d]))
    enc_inputs = [ctx, wx, wh, b, proj]
    grad_names = ["d_ctx_emb", "d_wx", "d_wh", "d_b", "d_proj"]

    em.emit(
        f"{prefix}_encode",
        functools.partial(model.lm_encode_entry, normalize=True),
        enc_inputs,
        ["h"],
        meta,
    )
    sampled_inputs = enc_inputs + [
        ("tgt_emb", spec([batch, d])),
        ("neg_emb", spec([m, d])),
        ("neg_adjust", spec([m])),
        ("neg_mask", spec([batch, m])),
    ]
    sampled_outputs = ["loss"] + grad_names + ["d_tgt_emb", "d_neg_emb"]
    em.emit(
        f"{prefix}_train_sampled",
        functools.partial(
            model.lm_train_sampled_entry, tau=tau, normalize=True,
            absolute=False,
        ),
        sampled_inputs,
        sampled_outputs,
        meta,
    )
    em.emit(
        f"{prefix}_train_sampled_abs",
        functools.partial(
            model.lm_train_sampled_entry, tau=tau, normalize=True,
            absolute=True,
        ),
        sampled_inputs,
        sampled_outputs,
        meta,
    )
    full_inputs = enc_inputs + [
        ("cls", spec([n, d])),
        ("targets", spec([batch], jnp.int32)),
    ]
    if full:
        em.emit(
            f"{prefix}_train_full",
            functools.partial(
                model.lm_train_full_entry, tau=tau, normalize=True,
                absolute=False,
            ),
            full_inputs,
            ["loss"] + grad_names + ["d_cls"],
            meta,
        )
    em.emit(
        f"{prefix}_eval",
        functools.partial(model.lm_eval_entry, tau=tau, normalize=True),
        full_inputs,
        ["loss"],
        meta,
    )
    if unnorm:
        em.emit(
            f"{prefix}_train_full_unnorm",
            functools.partial(
                model.lm_train_full_entry, tau=tau, normalize=False,
                absolute=False,
            ),
            full_inputs,
            ["loss"] + grad_names + ["d_cls"],
            meta,
        )
        em.emit(
            f"{prefix}_eval_unnorm",
            functools.partial(
                model.lm_eval_entry, tau=tau, normalize=False
            ),
            full_inputs,
            ["loss"],
            meta,
        )


def emit_xc(em, prefix, cfg, *, full=True, unnorm=False):
    n, d, nnz, batch, m, tau = (
        cfg["n"], cfg["d"], cfg["nnz"], cfg["batch"], cfg["m"], cfg["tau"],
    )
    meta = {"kind": "xc", **cfg}
    feat = ("feat_emb", spec([batch, nnz, d]))
    vals = ("vals", spec([batch, nnz]))
    sampled_inputs = [
        feat, vals,
        ("tgt_emb", spec([batch, d])),
        ("neg_emb", spec([m, d])),
        ("neg_adjust", spec([m])),
        ("neg_mask", spec([batch, m])),
    ]
    sampled_outputs = ["loss", "d_feat_emb", "d_tgt_emb", "d_neg_emb"]
    em.emit(
        f"{prefix}_train_sampled",
        functools.partial(
            model.xc_train_sampled_entry, tau=tau, normalize=True,
            absolute=False,
        ),
        sampled_inputs,
        sampled_outputs,
        meta,
    )
    em.emit(
        f"{prefix}_train_sampled_abs",
        functools.partial(
            model.xc_train_sampled_entry, tau=tau, normalize=True,
            absolute=True,
        ),
        sampled_inputs,
        sampled_outputs,
        meta,
    )
    full_inputs = [
        feat, vals,
        ("cls", spec([n, d])),
        ("targets", spec([batch], jnp.int32)),
    ]
    if full:
        em.emit(
            f"{prefix}_train_full",
            functools.partial(
                model.xc_train_full_entry, tau=tau, normalize=True,
                absolute=False,
            ),
            full_inputs,
            ["loss", "d_feat_emb", "d_cls"],
            meta,
        )
    scores_inputs = [feat, vals, ("cls", spec([n, d]))]
    em.emit(
        f"{prefix}_scores",
        functools.partial(model.xc_scores_entry, tau=tau, normalize=True),
        scores_inputs,
        ["scores"],
        meta,
    )
    if unnorm:
        em.emit(
            f"{prefix}_train_full_unnorm",
            functools.partial(
                model.xc_train_full_entry, tau=tau, normalize=False,
                absolute=False,
            ),
            full_inputs,
            ["loss", "d_feat_emb", "d_cls"],
            meta,
        )
        em.emit(
            f"{prefix}_scores_unnorm",
            functools.partial(
                model.xc_scores_entry, tau=tau, normalize=False
            ),
            scores_inputs,
            ["scores"],
            meta,
        )


def emit_rff_map(em):
    from .kernels.rff_map import rff_map

    cfg = RFF_MAP_CONFIG
    em.emit(
        "rff_map",
        rff_map_entry,
        [
            ("u", spec([cfg["rows"], cfg["d"]])),
            ("w", spec([cfg["num_freqs"], cfg["d"]])),
        ],
        ["phi"],
        {"kind": "rff_map", **cfg},
    )


def rff_map_entry(u, w):
    from .kernels.rff_map import rff_map

    return (rff_map(u, w),)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="quickstart,ptb,bnews,xc_amazon,xc_delicious,xc_wiki,rff_map",
        help="comma-separated config names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    wanted = set(args.configs.split(","))
    em = Emitter(args.out)

    print("lowering entry points (HLO text):")
    if "rff_map" in wanted:
        emit_rff_map(em)
    for name, cfg in LM_CONFIGS.items():
        if name not in wanted:
            continue
        emit_lm(
            em, name, cfg,
            # FULL baseline only where the paper runs it (PTB + tiny);
            # the Bnews figure has no FULL curve and the dense (n, d)
            # gradient would dominate compile + step time there.
            full=(name in ("quickstart", "ptb")),
            unnorm=(name == "ptb"),
        )
    for name, cfg in XC_CONFIGS.items():
        if name not in wanted:
            continue
        emit_xc(
            em, name, cfg,
            full=(name == "xc_amazon"),
            unnorm=(name == "xc_amazon"),
        )
    em.write_manifest()


if __name__ == "__main__":
    sys.exit(main())
