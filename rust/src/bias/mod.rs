//! Gradient-bias measurement harness — the empirical counterpart of
//! Theorem 1.
//!
//! Works in logit space (`∇_θ o_i = e_i`, `M = 1`), where the theorem's
//! statement is exact and fully observable:
//!
//! * [`empirical_bias`] Monte-Carlo-estimates `E[∇L′] − ∇L ∈ ℝⁿ` for any
//!   [`Sampler`];
//! * [`TheoremDiagnostics`] computes the three distribution-quality
//!   functionals of eq. 12 (plus the UB₁ magnitude of eq. 11), which the
//!   `bias_ablation` bench reports per sampler — this is the paper's
//!   predicted ordering RFF < uniform, EXP ≈ 0.

use crate::linalg::dot;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::sampler::Sampler;
use crate::softmax::{full_softmax_grad, sampled_softmax_loss, scatter_grad};

/// Result of a Monte-Carlo bias estimate.
#[derive(Clone, Debug)]
pub struct BiasEstimate {
    /// `E[∇L′] − ∇L` per logit coordinate.
    pub bias: Vec<f64>,
    /// ‖bias‖∞.
    pub linf: f64,
    /// ‖bias‖₂.
    pub l2: f64,
    /// Standard error (max over coordinates) of the Monte-Carlo estimate,
    /// to judge significance of `linf`.
    pub max_se: f64,
    pub trials: usize,
}

/// Monte-Carlo estimate of the gradient bias of sampled softmax under
/// `sampler`, for one `(h, target)` and `m` negatives per draw.
pub fn empirical_bias(
    classes: &Matrix,
    h: &[f32],
    target: usize,
    tau: f32,
    sampler: &dyn Sampler,
    m: usize,
    trials: usize,
    rng: &mut Rng,
) -> BiasEstimate {
    let n = classes.rows();
    let logits: Vec<f64> = (0..n)
        .map(|i| (tau * dot(h, classes.row(i))) as f64)
        .collect();
    let g_full = full_softmax_grad(&logits, target);

    let mut mean = vec![0.0f64; n];
    let mut m2 = vec![0.0f64; n];
    for k in 0..trials {
        let draw = sampler.sample_negatives(h, target, m, rng);
        let negs: Vec<f64> =
            draw.ids.iter().map(|&i| logits[i as usize]).collect();
        let s = sampled_softmax_loss(logits[target], &negs, &draw.probs);
        let g = scatter_grad(n, target, &draw.ids, &s.grad);
        // Welford per-coordinate.
        for i in 0..n {
            let delta = g[i] - mean[i];
            mean[i] += delta / (k + 1) as f64;
            m2[i] += delta * (g[i] - mean[i]);
        }
    }
    let bias: Vec<f64> =
        mean.iter().zip(&g_full).map(|(e, f)| e - f).collect();
    let linf = bias.iter().fold(0.0f64, |a, b| a.max(b.abs()));
    let l2 = bias.iter().map(|b| b * b).sum::<f64>().sqrt();
    let max_se = m2
        .iter()
        .map(|v| (v / (trials.max(2) - 1) as f64 / trials as f64).sqrt())
        .fold(0.0f64, f64::max);
    BiasEstimate { bias, linf, l2, max_se, trials }
}

/// The three sampling-distribution functionals of Theorem 1 / eq. 12,
/// evaluated exactly for a given `(h, target)`.
#[derive(Clone, Debug)]
pub struct TheoremDiagnostics {
    /// `Σ_{j∈N_t} e^{2o_j}/q_j` — minimized (= Z_t²) iff q ∝ e^o.
    pub sum_sq_over_q: f64,
    /// Its Cauchy–Schwarz floor `Z_t²`.
    pub floor: f64,
    /// `max_{i,i'} |e^{o_i}/q_i − e^{o_{i'}}/q_{i'}|` (drives UB₂).
    pub max_ratio_gap: f64,
    /// `max_k |Z_t − e^{o_k}/q_k|` (drives LB).
    pub max_lb_gap: f64,
    /// The UB₁ magnitude `(Σ e^{2o}/q − Z_t²)/(m·Z³)` of eq. 11.
    pub ub1: f64,
}

/// Evaluate the Theorem-1 diagnostics for a sampler. `q` is taken
/// conditioned on excluding the target (the theorem's sampling model).
pub fn theorem_diagnostics(
    classes: &Matrix,
    h: &[f32],
    target: usize,
    tau: f32,
    sampler: &dyn Sampler,
    m: usize,
) -> TheoremDiagnostics {
    let n = classes.rows();
    let logits: Vec<f64> = (0..n)
        .map(|i| (tau * dot(h, classes.row(i))) as f64)
        .collect();
    // Stabilize exp() by shifting logits; every eq.-12 quantity is then a
    // *relative* statement (we report shifted values consistently; ratios
    // and the UB₁ normalization are shift-covariant as Z shifts too).
    let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = logits.iter().map(|&o| (o - mx).exp()).collect();
    let z: f64 = e.iter().sum();
    let z_t: f64 = z - e[target];

    let q_t = sampler.probability(h, target);
    let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);

    let mut sum_sq_over_q = 0.0;
    let mut ratios: Vec<f64> = Vec::with_capacity(n - 1);
    for j in 0..n {
        if j == target {
            continue;
        }
        let q = (sampler.probability(h, j) / renorm).max(f64::MIN_POSITIVE);
        sum_sq_over_q += e[j] * e[j] / q;
        ratios.push(e[j] / q);
    }
    let rmax = ratios.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let rmin = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_lb_gap = ratios
        .iter()
        .map(|r| (z_t - r).abs())
        .fold(0.0f64, f64::max);
    TheoremDiagnostics {
        sum_sq_over_q,
        floor: z_t * z_t,
        max_ratio_gap: rmax - rmin,
        max_lb_gap,
        ub1: (sum_sq_over_q - z_t * z_t) / (m as f64 * z * z * z),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;
    use crate::sampler::{ExactSoftmaxSampler, UniformSampler};

    fn setup(rng: &mut Rng, n: usize, d: usize) -> (Matrix, Vec<f32>) {
        let classes = Matrix::randn(rng, n, d).l2_normalized_rows();
        let h = unit_vector(rng, d);
        (classes, h)
    }

    #[test]
    fn exact_sampler_has_negligible_bias() {
        let mut rng = Rng::seeded(131);
        let (classes, h) = setup(&mut rng, 20, 8);
        let tau = 4.0;
        let sampler = ExactSoftmaxSampler::new(&classes, tau);
        let est = empirical_bias(
            &classes, &h, 0, tau, &sampler, 10, 4000, &mut rng,
        );
        // Exact-softmax sampling ⇒ bias O(1/m); must be small and within a
        // few standard errors of the uniform sampler's bias scale.
        assert!(
            est.linf < 0.02 + 4.0 * est.max_se,
            "EXP bias too large: {} (se {})",
            est.linf,
            est.max_se
        );
    }

    #[test]
    fn uniform_bias_exceeds_exact_bias() {
        // The Theorem-1 story: a skewed softmax + uniform q ⇒ larger bias
        // than exact sampling at the same m.
        let mut rng = Rng::seeded(132);
        let n = 30;
        let d = 8;
        let (mut classes, h) = setup(&mut rng, n, d);
        // Plant strong skew: a few classes very close to h.
        for i in 0..3 {
            let row = classes.row_mut(i);
            for (r, &hv) in row.iter_mut().zip(h.iter()) {
                *r = hv + 0.05 * (i as f32 + 1.0);
            }
            crate::linalg::l2_normalize(row);
        }
        let tau = 8.0;
        let m = 5;
        let trials = 3000;
        let exact = ExactSoftmaxSampler::new(&classes, tau);
        let uniform = UniformSampler::new(n);
        let be = empirical_bias(
            &classes, &h, 5, tau, &exact, m, trials, &mut rng,
        );
        let bu = empirical_bias(
            &classes, &h, 5, tau, &uniform, m, trials, &mut rng,
        );
        assert!(
            bu.l2 > be.l2,
            "uniform bias {} should exceed exact bias {}",
            bu.l2,
            be.l2
        );
    }

    #[test]
    fn diagnostics_floor_attained_by_exact_sampler() {
        let mut rng = Rng::seeded(133);
        let (classes, h) = setup(&mut rng, 25, 6);
        let tau = 5.0;
        let exact = ExactSoftmaxSampler::new(&classes, tau);
        let d = theorem_diagnostics(&classes, &h, 2, tau, &exact, 10);
        // q ∝ e^o ⇒ Σ e^{2o}/q = Z_t² exactly (eq. 13 equality case).
        assert!(
            (d.sum_sq_over_q - d.floor).abs() / d.floor < 1e-6,
            "{} vs floor {}",
            d.sum_sq_over_q,
            d.floor
        );
        assert!(d.ub1.abs() < 1e-9);
        // e^{o_j}/q_j is constant (= Z_t) ⇒ both gaps vanish.
        assert!(d.max_ratio_gap / d.floor.sqrt() < 1e-6);
        assert!(d.max_lb_gap / d.floor.sqrt() < 1e-6);
    }

    #[test]
    fn diagnostics_uniform_worse_than_exact() {
        let mut rng = Rng::seeded(134);
        let (classes, h) = setup(&mut rng, 25, 6);
        let tau = 8.0;
        let exact = ExactSoftmaxSampler::new(&classes, tau);
        let uniform = UniformSampler::new(25);
        let de = theorem_diagnostics(&classes, &h, 2, tau, &exact, 10);
        let du = theorem_diagnostics(&classes, &h, 2, tau, &uniform, 10);
        assert!(du.ub1 > de.ub1, "uniform UB1 {} vs exact {}", du.ub1, de.ub1);
        assert!(du.max_ratio_gap > de.max_ratio_gap);
    }

    #[test]
    fn bias_shrinks_with_m() {
        // Theorem 1: every bias term carries a 1/m factor.
        let mut rng = Rng::seeded(135);
        let (classes, h) = setup(&mut rng, 20, 6);
        let tau = 6.0;
        let uniform = UniformSampler::new(20);
        let trials = 6000;
        let small = empirical_bias(
            &classes, &h, 1, tau, &uniform, 2, trials, &mut rng,
        );
        let large = empirical_bias(
            &classes, &h, 1, tau, &uniform, 16, trials, &mut rng,
        );
        assert!(
            large.l2 < small.l2,
            "bias should shrink with m: m=2 → {}, m=16 → {}",
            small.l2,
            large.l2
        );
    }
}
