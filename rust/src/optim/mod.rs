//! First-order optimizers, applied by the L3 coordinator to the
//! [`crate::model::ParamStore`] after each PJRT step. All support both
//! dense block updates and **sparse row updates** (only the target +
//! sampled class-embedding rows change each step — the update pattern
//! sampled softmax exists to enable).
//!
//! Gradient clipping is per-coordinate (`clip`), matching Theorem 1's
//! bounded-gradient assumption (footnote 3 of the paper).

use std::collections::BTreeMap;

/// Optimizer state slot per (block, parameter) as needed.
#[derive(Clone, Debug, Default)]
struct Slot {
    /// First moment / momentum / accumulator (algorithm-dependent).
    m: Vec<f32>,
    /// Second moment (Adam only).
    v: Vec<f32>,
}

/// Which algorithm an [`Optimizer`] runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algo {
    Sgd,
    /// Heavy-ball momentum with coefficient β.
    Momentum { beta: f32 },
    /// Adagrad with accumulator floor ε.
    Adagrad { eps: f32 },
    /// Adam (β₁, β₂, ε). Bias correction uses a per-block step count.
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

/// A stateful optimizer over identified parameter blocks.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub algo: Algo,
    pub lr: f32,
    /// Per-coordinate gradient clip (0 ⇒ disabled).
    pub clip: f32,
    slots: BTreeMap<usize, Slot>,
    steps: BTreeMap<usize, u64>,
}

impl Optimizer {
    pub fn new(algo: Algo, lr: f32, clip: f32) -> Self {
        assert!(lr > 0.0, "Optimizer: lr must be > 0");
        assert!(clip >= 0.0);
        Self { algo, lr, clip, slots: BTreeMap::new(), steps: BTreeMap::new() }
    }

    pub fn sgd(lr: f32, clip: f32) -> Self {
        Self::new(Algo::Sgd, lr, clip)
    }

    pub fn momentum(lr: f32, beta: f32, clip: f32) -> Self {
        Self::new(Algo::Momentum { beta }, lr, clip)
    }

    pub fn adagrad(lr: f32, clip: f32) -> Self {
        Self::new(Algo::Adagrad { eps: 1e-8 }, lr, clip)
    }

    pub fn adam(lr: f32, clip: f32) -> Self {
        Self::new(Algo::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, lr, clip)
    }

    pub fn from_config(cfg: &crate::config::TrainConfig) -> Self {
        use crate::config::OptimizerKind::*;
        match cfg.optimizer {
            Sgd => Self::sgd(cfg.lr, cfg.grad_clip),
            Momentum => Self::momentum(cfg.lr, 0.9, cfg.grad_clip),
            Adagrad => Self::adagrad(cfg.lr, cfg.grad_clip),
            Adam => Self::adam(cfg.lr, cfg.grad_clip),
        }
    }

    fn slot(&mut self, block: usize, numel: usize, need_v: bool) -> &mut Slot {
        let slot = self.slots.entry(block).or_default();
        if slot.m.len() != numel {
            slot.m = vec![0.0; numel];
        }
        if need_v && slot.v.len() != numel {
            slot.v = vec![0.0; numel];
        }
        slot
    }

    /// Grow a block's state to `new_numel`, padding with zeros — the
    /// dynamic-vocabulary path: existing accumulators keep their history
    /// (the lazy `slot()` sizing would otherwise RESET the whole block's
    /// state on the first post-growth update), new rows start cold. A
    /// no-op for blocks that have no state yet (it will be created lazily
    /// at the right size).
    pub fn grow_state(&mut self, block: usize, new_numel: usize) {
        if let Some(slot) = self.slots.get_mut(&block) {
            if !slot.m.is_empty() && slot.m.len() < new_numel {
                slot.m.resize(new_numel, 0.0);
            }
            if !slot.v.is_empty() && slot.v.len() < new_numel {
                slot.v.resize(new_numel, 0.0);
            }
        }
    }

    /// Dense update of a whole block: `param -= lr * step(grad)`.
    pub fn update_dense(&mut self, block: usize, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        let indices: Vec<usize> = (0..param.len()).collect();
        self.update_at(block, param, grad, &indices, param.len());
    }

    /// Sparse update: `grad` holds one packed gradient value per entry of
    /// `coords` (flat indices into the block).
    pub fn update_sparse(
        &mut self,
        block: usize,
        param: &mut [f32],
        coords: &[usize],
        grad: &[f32],
    ) {
        assert_eq!(coords.len(), grad.len());
        let numel = param.len();
        self.update_at(block, param, grad, coords, numel);
    }

    /// Sparse *row* update for 2-D blocks: `grads` is `rows.len() × cols`
    /// packed row-major.
    pub fn update_rows(
        &mut self,
        block: usize,
        param: &mut [f32],
        cols: usize,
        rows: &[usize],
        grads: &[f32],
    ) {
        assert_eq!(grads.len(), rows.len() * cols);
        let mut coords = Vec::with_capacity(grads.len());
        for &r in rows {
            for c in 0..cols {
                coords.push(r * cols + c);
            }
        }
        let numel = param.len();
        self.update_at(block, param, grads, &coords, numel);
    }

    fn update_at(
        &mut self,
        block: usize,
        param: &mut [f32],
        grad: &[f32],
        coords: &[usize],
        numel: usize,
    ) {
        let lr = self.lr;
        let clip = self.clip;
        let clipg = |g: f32| if clip > 0.0 { g.clamp(-clip, clip) } else { g };
        match self.algo {
            Algo::Sgd => {
                for (&c, &g) in coords.iter().zip(grad.iter()) {
                    param[c] -= lr * clipg(g);
                }
            }
            Algo::Momentum { beta } => {
                let slot = self.slot(block, numel, false);
                for (&c, &g) in coords.iter().zip(grad.iter()) {
                    let g = clipg(g);
                    slot.m[c] = beta * slot.m[c] + g;
                    param[c] -= lr * slot.m[c];
                }
            }
            Algo::Adagrad { eps } => {
                let slot = self.slot(block, numel, false);
                for (&c, &g) in coords.iter().zip(grad.iter()) {
                    let g = clipg(g);
                    slot.m[c] += g * g;
                    param[c] -= lr * g / (slot.m[c].sqrt() + eps);
                }
            }
            Algo::Adam { beta1, beta2, eps } => {
                let t = {
                    let e = self.steps.entry(block).or_insert(0);
                    *e += 1;
                    *e
                };
                let slot = self.slot(block, numel, true);
                let bc1 = 1.0 - beta1.powi(t as i32);
                let bc2 = 1.0 - beta2.powi(t as i32);
                for (&c, &g) in coords.iter().zip(grad.iter()) {
                    let g = clipg(g);
                    slot.m[c] = beta1 * slot.m[c] + (1.0 - beta1) * g;
                    slot.v[c] = beta2 * slot.v[c] + (1.0 - beta2) * g * g;
                    let mhat = slot.m[c] / bc1;
                    let vhat = slot.v[c] / bc2;
                    param[c] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = ½‖x − target‖² and require convergence.
    fn converges(mut opt: Optimizer, steps: usize, tol: f32) {
        let target = [1.0f32, -2.0, 0.5];
        let mut x = [0.0f32; 3];
        for _ in 0..steps {
            let grad: Vec<f32> =
                x.iter().zip(&target).map(|(xi, ti)| xi - ti).collect();
            opt.update_dense(0, &mut x, &grad);
        }
        for (xi, ti) in x.iter().zip(&target) {
            assert!(
                (xi - ti).abs() < tol,
                "{:?} did not converge: {x:?}",
                opt.algo
            );
        }
    }

    #[test]
    fn sgd_converges() {
        converges(Optimizer::sgd(0.1, 0.0), 200, 1e-3);
    }

    #[test]
    fn momentum_converges() {
        converges(Optimizer::momentum(0.05, 0.9, 0.0), 300, 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        converges(Optimizer::adagrad(0.5, 0.0), 800, 2e-2);
    }

    #[test]
    fn adam_converges() {
        converges(Optimizer::adam(0.05, 0.0), 600, 1e-2);
    }

    #[test]
    fn clipping_limits_step() {
        let mut opt = Optimizer::sgd(1.0, 0.5);
        let mut x = [0.0f32];
        opt.update_dense(0, &mut x, &[100.0]);
        assert!((x[0] + 0.5).abs() < 1e-6, "clip failed: {}", x[0]);
    }

    #[test]
    fn sparse_row_update_touches_only_rows() {
        let mut opt = Optimizer::sgd(1.0, 0.0);
        let mut param = vec![0.0f32; 4 * 3]; // 4 rows × 3 cols
        let grads = vec![1.0f32; 2 * 3];
        opt.update_rows(0, &mut param, 3, &[1, 3], &grads);
        assert!(param[0..3].iter().all(|&v| v == 0.0));
        assert!(param[3..6].iter().all(|&v| v == -1.0));
        assert!(param[6..9].iter().all(|&v| v == 0.0));
        assert!(param[9..12].iter().all(|&v| v == -1.0));
    }

    #[test]
    fn adagrad_sparse_state_is_per_coordinate() {
        // Two updates to row 0 must decay its effective lr, while row 1's
        // first update uses the full lr.
        let mut opt = Optimizer::adagrad(1.0, 0.0);
        let mut param = vec![0.0f32; 2 * 2];
        opt.update_rows(0, &mut param, 2, &[0], &[1.0, 1.0]);
        let after_first = param[0];
        opt.update_rows(0, &mut param, 2, &[0], &[1.0, 1.0]);
        let second_step = param[0] - after_first;
        opt.update_rows(0, &mut param, 2, &[1], &[1.0, 1.0]);
        let fresh_step = param[2];
        assert!(second_step.abs() < fresh_step.abs());
    }

    #[test]
    fn separate_blocks_have_separate_state() {
        let mut opt = Optimizer::adagrad(1.0, 0.0);
        let mut a = vec![0.0f32; 2];
        let mut b = vec![0.0f32; 2];
        opt.update_dense(0, &mut a, &[1.0, 1.0]);
        opt.update_dense(0, &mut a, &[1.0, 1.0]);
        opt.update_dense(1, &mut b, &[1.0, 1.0]);
        // Block 1's first step is un-decayed.
        assert!((b[0] - a[0] / 2.0).abs() > 0.1);
    }
}
