//! Small helpers shared by the bench harnesses (`rust/benches/*`): build
//! a config from key/value overrides, run one training, and format
//! perplexity curves as paper-style table rows.

use super::{TrainReport, TrainerBuilder};
use crate::config::Config;
use crate::runtime::Runtime;
use crate::tables::Table;
use anyhow::Result;

/// Number of training steps for figure benches, scaled by
/// `RFSM_BENCH_STEPS` (default 240; set higher for smoother curves).
pub fn bench_steps(default: usize) -> usize {
    std::env::var("RFSM_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Build a config from `--section.key=value`-style pairs.
pub fn config_from(pairs: &[(&str, String)]) -> Result<Config> {
    let mut cfg = Config::default();
    for (k, v) in pairs {
        cfg.set(k, v).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    Ok(cfg)
}

/// Model-shape preset for a named corpus/dataset prefix. On the native
/// backend these *are* the kernel shapes (the pjrt backend reads shapes
/// from the artifact manifest and ignores them); unknown prefixes keep
/// the config defaults (= the PTB scale).
pub fn prefix_preset(cfg: &mut Config, prefix: &str) -> Result<()> {
    let pairs: &[(&str, &str)] = match prefix {
        "quickstart" => &[
            ("model.kind", "lm"),
            ("model.num_classes", "1000"),
            ("model.embed_dim", "64"),
            ("model.hidden_dim", "96"),
            ("model.seq_len", "12"),
        ],
        // Bnews scale: n·d embedding + class tables ≈ 26M of the ~34M
        // total parameters.
        "bnews" => &[("model.kind", "lm"), ("model.num_classes", "64000")],
        // Planted XC label spaces, scale-reduced from the real
        // benchmarks to fit the single-core testbed.
        "xc_amazon" => &[
            ("model.kind", "extreme"),
            ("model.num_classes", "13000"),
            ("model.embed_dim", "64"),
        ],
        "xc_delicious" => &[
            ("model.kind", "extreme"),
            ("model.num_classes", "20000"),
            ("model.embed_dim", "64"),
        ],
        "xc_wiki" => &[
            ("model.kind", "extreme"),
            ("model.num_classes", "32000"),
            ("model.embed_dim", "64"),
        ],
        _ => &[],
    };
    for (k, v) in pairs {
        cfg.set(k, v).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    Ok(())
}

/// [`config_from`] with the [`prefix_preset`] applied first, so the
/// explicit pairs win over the preset.
pub fn corpus_config(
    prefix: &str,
    pairs: &[(&str, String)],
) -> Result<Config> {
    let mut cfg = Config::default();
    prefix_preset(&mut cfg, prefix)?;
    for (k, v) in pairs {
        cfg.set(k, v).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    Ok(cfg)
}

/// Run one training and return its report (printing progress).
pub fn train_once(
    runtime: &Runtime,
    prefix: &str,
    label: &str,
    cfg: Config,
) -> Result<TrainReport> {
    println!("  [{label}] training…");
    let mut t = TrainerBuilder::new(runtime, prefix, cfg).build()?;
    let r = t.run()?;
    println!(
        "  [{label}] final metric {:.2} in {:.1}s",
        r.final_metric, r.wall_seconds
    );
    Ok(r)
}

/// Render a set of labeled training curves (validation metric per eval
/// step) as one table — the text analogue of the paper's figures.
pub fn curves_table(title: &str, runs: &[(String, TrainReport)]) -> Table {
    let steps: Vec<usize> = runs
        .first()
        .map(|(_, r)| r.history.iter().map(|p| p.step).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["step".into()];
    header.extend(runs.iter().map(|(n, _)| n.clone()));
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &refs);
    for (i, s) in steps.iter().enumerate() {
        let mut row = vec![s.to_string()];
        for (_, r) in runs {
            row.push(
                r.history
                    .get(i)
                    .map(|p| format!("{:.1}", p.metric))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_from_pairs() {
        let cfg = config_from(&[
            ("sampler.kind", "uniform".to_string()),
            ("train.steps", "7".to_string()),
        ])
        .unwrap();
        assert_eq!(cfg.train.steps, 7);
    }

    #[test]
    fn prefix_presets_resolve_shapes() {
        let mut cfg = Config::default();
        prefix_preset(&mut cfg, "xc_amazon").unwrap();
        assert_eq!(cfg.model.num_classes, 13_000);
        assert_eq!(cfg.model.kind.name(), "extreme");
        // Explicit pairs win over the preset.
        let cfg = corpus_config(
            "bnews",
            &[("model.num_classes", "777".to_string())],
        )
        .unwrap();
        assert_eq!(cfg.model.num_classes, 777);
    }

    #[test]
    fn bench_steps_default() {
        std::env::remove_var("RFSM_BENCH_STEPS");
        assert_eq!(bench_steps(240), 240);
    }
}
