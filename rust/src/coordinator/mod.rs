//! The L3 training coordinator — the paper's system integrated as a
//! framework feature.
//!
//! The [`Trainer`] owns the full training lifecycle:
//!
//! 1. **data** — synthetic corpus / extreme-classification batches
//!    (prefetched on a producer thread with bounded depth);
//! 2. **sampling service** — the configured negative sampler (RF-softmax
//!    kernel tree or a baseline), including the logit adjustment
//!    `log(m·q)` and accidental-hit masks;
//! 3. **execution** — on the default **native** backend, one fused
//!    in-process step (forward + one-pass sampled loss/grad + backward,
//!    [`crate::runtime::native`]) over reusable scratch; on the optional
//!    **pjrt** backend (cargo feature `pjrt`), one PJRT call per step
//!    against the AOT artifacts (`{prefix}_train_sampled`,
//!    `{prefix}_train_full`, `{prefix}_eval`, …) whose shapes are *read
//!    from the manifest*, not assumed;
//! 4. **state** — the [`ParamStore`] and optimizer; sparse row updates for
//!    embedding tables, dense updates for the rest;
//! 5. **propagation** — updated class embeddings pushed back into the
//!    sampling tree (`O(D log n)` per touched class, paper §3.1);
//! 6. **metrics** — per-phase timers and loss curves, dumped as JSON.
//!
//! Native model shapes come from the [`Config`]; on pjrt they are
//! discovered from `artifacts/manifest.json` instead, so the Rust side
//! can never drift from what the Python AOT pipeline compiled.

pub mod harness;
mod lm;
mod sampler_service;
mod xc;

pub use lm::LmTrainer;
pub use sampler_service::{build_sampler, SamplerService};
pub use xc::XcTrainer;

use crate::admin::AdminSurface;
use crate::config::{Config, SamplerKind};
use crate::json::Json;
use crate::metrics::Metrics;
use crate::runtime::Runtime;
use anyhow::{bail, Result};

/// One evaluation point on the training curve.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    /// Fractional epoch at this step.
    pub epoch: f64,
    /// Smoothed training loss (sampled or full, whichever is optimized).
    pub train_loss: f64,
    /// Full-softmax validation loss.
    pub eval_loss: f64,
    /// Task metric: perplexity (LM) or PREC@1 (extreme).
    pub metric: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub sampler: String,
    pub history: Vec<EvalPoint>,
    pub final_metric: f64,
    pub final_eval_loss: f64,
    pub steps_run: usize,
    pub wall_seconds: f64,
    pub metrics: Json,
}

impl TrainReport {
    /// Render the history as a compact curve string for logs.
    pub fn curve(&self) -> String {
        self.history
            .iter()
            .map(|p| format!("({}, {:.2})", p.step, p.metric))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sampler", Json::from(self.sampler.as_str())),
            ("final_metric", Json::from(self.final_metric)),
            ("final_eval_loss", Json::from(self.final_eval_loss)),
            ("steps", Json::from(self.steps_run)),
            ("wall_seconds", Json::from(self.wall_seconds)),
            (
                "history",
                Json::Arr(
                    self.history
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("step", Json::from(p.step)),
                                ("epoch", Json::from(p.epoch)),
                                ("train_loss", Json::from(p.train_loss)),
                                ("eval_loss", Json::from(p.eval_loss)),
                                ("metric", Json::from(p.metric)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Task-dispatching trainer facade. Examples and benches construct this
/// via [`TrainerBuilder`] and call [`Trainer::run`].
pub enum Trainer<'rt> {
    Lm(LmTrainer<'rt>),
    Xc(XcTrainer<'rt>),
}

impl<'rt> Trainer<'rt> {
    pub fn run(&mut self) -> Result<TrainReport> {
        match self {
            Trainer::Lm(t) => t.run(),
            Trainer::Xc(t) => t.run(),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        match self {
            Trainer::Lm(t) => &t.metrics,
            Trainer::Xc(t) => &t.metrics,
        }
    }
}

/// Builder resolving artifacts + data + sampler from a [`Config`] and an
/// artifact prefix (e.g. `"ptb"`, `"bnews"`, `"xc_amazon"`).
pub struct TrainerBuilder<'rt> {
    runtime: &'rt Runtime,
    prefix: String,
    config: Config,
    /// Sample negatives with the previous step's query embedding,
    /// skipping the per-step encoder pass (systems ablation; see
    /// DESIGN.md §Perf).
    pub stale_sampling: bool,
    /// Use the unnormalized-embedding artifact variants (`*_unnorm`) —
    /// the paper's §4.2 normalization ablation. FULL sampler only.
    pub unnormalized: bool,
}

impl<'rt> TrainerBuilder<'rt> {
    pub fn new(runtime: &'rt Runtime, prefix: &str, config: Config) -> Self {
        Self {
            runtime,
            prefix: prefix.to_string(),
            config,
            stale_sampling: false,
            unnormalized: false,
        }
    }

    pub fn stale_sampling(mut self, on: bool) -> Self {
        self.stale_sampling = on;
        self
    }

    pub fn unnormalized(mut self, on: bool) -> Self {
        self.unnormalized = on;
        self
    }

    pub fn build(self) -> Result<Trainer<'rt>> {
        // Native backend: the task kind comes from the config itself.
        // Pjrt: from the train artifact's manifest meta, so a stale or
        // mismatched artifact directory fails loudly here.
        let kind = if self.runtime.is_native() {
            self.config.model.kind.name().to_string()
        } else {
            let key = format!("{}_train_sampled", self.prefix);
            let meta = match self.runtime.manifest().get(&key) {
                Some(m) => m,
                None => bail!(
                    "no artifact '{key}' in manifest — is the prefix right? \
                     available: {}",
                    self.runtime.manifest().names().join(", ")
                ),
            };
            meta.meta
                .get("kind")
                .and_then(|k| k.as_str())
                .unwrap_or("lm")
                .to_string()
        };
        if self.unnormalized {
            anyhow::ensure!(
                self.config.sampler.kind == SamplerKind::Full,
                "unnormalized mode is a FULL-softmax ablation (paper §4.2)"
            );
        }
        match kind.as_str() {
            "lm" => Ok(Trainer::Lm(LmTrainer::new(
                self.runtime,
                &self.prefix,
                self.config,
                self.stale_sampling,
                self.unnormalized,
            )?)),
            "xc" | "extreme" => Ok(Trainer::Xc(XcTrainer::new(
                self.runtime,
                &self.prefix,
                self.config,
                self.unnormalized,
            )?)),
            other => bail!("unknown task kind '{other}'"),
        }
    }
}

/// Shared trainer-side vocabulary growth (LmTrainer/XcTrainer both
/// delegate here so the CLS/optimizer/sampler sequencing can never
/// drift between tasks): extend the sampling service, verify the
/// assigned ids continue the CLS block's rows, grow the block in place,
/// and zero-pad the optimizer state (preserving accumulator history).
pub(crate) fn extend_vocab_impl(
    service: Option<&mut sampler_service::SamplerService>,
    params: &mut crate::model::ParamStore,
    optimizer: &mut crate::optim::Optimizer,
    metrics: &mut Metrics,
    cls_block: usize,
    d: usize,
    embeddings: &crate::linalg::Matrix,
) -> Result<Vec<u32>> {
    if embeddings.rows() == 0 {
        return Ok(Vec::new()); // a no-label step is not an error
    }
    anyhow::ensure!(
        embeddings.cols() == d,
        "extend_vocab: embedding dim {} != d {d}",
        embeddings.cols()
    );
    let expected = params.get(cls_block).rows() as u32;
    let svc = service.ok_or_else(|| {
        anyhow::anyhow!("extend_vocab: FULL softmax has no sampling service")
    })?;
    let (ids, _epoch) = svc
        .admin_add(embeddings.clone())
        .map_err(|e| anyhow::anyhow!("extend_vocab: {e}"))?;
    anyhow::ensure!(
        ids.first().copied() == Some(expected),
        "extend_vocab: sampler assigned ids from {:?} but CLS has \
         {expected} rows — sampler/trainer state diverged",
        ids.first()
    );
    let cls = params.get_mut(cls_block);
    cls.append_rows(embeddings);
    let numel = cls.numel();
    optimizer.grow_state(cls_block, numel);
    metrics.incr("vocab_added", ids.len() as u64);
    Ok(ids)
}

/// Shared trainer-side retirement. **Precondition**: once a class is
/// retired, the data stream must stop producing it as a *target* — a
/// retired target reaching `sample_negatives` is an invariant violation
/// that panics (the batch pipeline owns its label space; validating
/// every batch's targets against holes on the hot path is not worth the
/// cost). Retired classes appearing as *negatives* cannot happen — the
/// sampler never emits holes.
pub(crate) fn retire_classes_impl(
    service: Option<&mut sampler_service::SamplerService>,
    metrics: &mut Metrics,
    ids: &[u32],
) -> Result<()> {
    let svc = service.ok_or_else(|| {
        anyhow::anyhow!("retire_classes: FULL softmax has no sampling service")
    })?;
    svc.admin_retire(ids.to_vec())
        .map_err(|e| anyhow::anyhow!("retire_classes: {e}"))?;
    metrics.incr("vocab_retired", ids.len() as u64);
    Ok(())
}

/// First `rows` rows of a 2-D parameter block as a tensor — the compiled
/// artifacts' fixed-shape view of a table that may have grown past it
/// via `extend_vocab`.
#[cfg(feature = "pjrt")]
pub(crate) fn block_rows_tensor(
    params: &crate::model::ParamStore,
    id: usize,
    rows: usize,
) -> crate::runtime::HostTensor {
    let b = params.get(id);
    let d = b.cols();
    crate::runtime::HostTensor::f32(&[rows, d], b.data[..rows * d].to_vec())
}

/// Reusable duplicate-summing row-gradient aggregator — the zero-
/// allocation counterpart of [`aggregate_rows`] for the native step
/// path. `begin` resets the aggregator for a new step while retaining
/// every buffer's capacity, so the steady-state `add` loop allocates
/// nothing once the per-step row population has been seen once.
/// Summing duplicates first matters for correctness, not just speed:
/// applying duplicate rows sequentially through a stateful optimizer
/// (Adagrad accumulators) would diverge from the dense semantics.
pub struct RowAggregator {
    index: std::collections::HashMap<u32, usize>,
    rows: Vec<usize>,
    grads: Vec<f32>,
    dim: usize,
}

impl RowAggregator {
    pub fn new() -> Self {
        Self {
            index: std::collections::HashMap::new(),
            rows: Vec::new(),
            grads: Vec::new(),
            dim: 0,
        }
    }

    /// Start a new step: clear contents, keep capacity.
    pub fn begin(&mut self, dim: usize) {
        self.index.clear();
        self.rows.clear();
        self.grads.clear();
        self.dim = dim;
    }

    /// Accumulate one row gradient (summing into the existing slot when
    /// `id` repeats within the step).
    pub fn add(&mut self, id: u32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.dim);
        let slot = if let Some(&s) = self.index.get(&id) {
            s
        } else {
            let s = self.rows.len();
            self.index.insert(id, s);
            self.rows.push(id as usize);
            self.grads.resize((s + 1) * self.dim, 0.0);
            s
        };
        let dst = &mut self.grads[slot * self.dim..(slot + 1) * self.dim];
        for (d, &x) in dst.iter_mut().zip(grad) {
            *d += x;
        }
    }

    /// Unique row ids touched this step, in first-seen order.
    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    /// Summed gradients, `rows().len() × dim`, matching `rows()` order.
    pub fn grads(&self) -> &[f32] {
        &self.grads
    }
}

impl Default for RowAggregator {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate per-row gradients with duplicate row ids: returns unique row
/// ids and their **summed** gradients (applying duplicates sequentially
/// through a stateful optimizer would be wrong).
pub fn aggregate_rows(
    ids: &[u32],
    grads: &[f32],
    dim: usize,
) -> (Vec<usize>, Vec<f32>) {
    assert_eq!(grads.len(), ids.len() * dim);
    let mut index: std::collections::HashMap<u32, usize> =
        std::collections::HashMap::with_capacity(ids.len());
    let mut unique: Vec<usize> = Vec::new();
    let mut summed: Vec<f32> = Vec::new();
    for (k, &id) in ids.iter().enumerate() {
        let slot = *index.entry(id).or_insert_with(|| {
            unique.push(id as usize);
            summed.extend(std::iter::repeat(0.0).take(dim));
            unique.len() - 1
        });
        let g = &grads[k * dim..(k + 1) * dim];
        let dst = &mut summed[slot * dim..(slot + 1) * dim];
        for (d, &x) in dst.iter_mut().zip(g) {
            *d += x;
        }
    }
    (unique, summed)
}

/// Was the run killed early by `$RFSM_MAX_STEPS` (CI guard)?
pub fn step_cap() -> Option<usize> {
    std::env::var("RFSM_MAX_STEPS").ok().and_then(|v| v.parse().ok())
}

/// Check that the configured sampler kind makes sense for training
/// (shared validation for both tasks).
pub(crate) fn validate_sampler_kind(kind: SamplerKind) -> Result<()> {
    // All kinds are supported; Full bypasses sampling entirely.
    let _ = kind;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_rows_sums_duplicates() {
        let ids = [3u32, 1, 3];
        let grads = [1.0f32, 1.0, 2.0, 2.0, 10.0, 10.0];
        let (unique, summed) = aggregate_rows(&ids, &grads, 2);
        assert_eq!(unique, vec![3, 1]);
        assert_eq!(summed, vec![11.0, 11.0, 2.0, 2.0]);
    }

    #[test]
    fn aggregate_rows_empty() {
        let (u, s) = aggregate_rows(&[], &[], 4);
        assert!(u.is_empty() && s.is_empty());
    }

    #[test]
    fn row_aggregator_matches_aggregate_rows() {
        let ids = [3u32, 1, 3, 7, 1];
        let grads: Vec<f32> = (0..ids.len() * 2).map(|i| i as f32).collect();
        let (unique, summed) = aggregate_rows(&ids, &grads, 2);
        let mut agg = RowAggregator::new();
        agg.begin(2);
        for (k, &id) in ids.iter().enumerate() {
            agg.add(id, &grads[k * 2..(k + 1) * 2]);
        }
        assert_eq!(agg.rows(), unique.as_slice());
        assert_eq!(agg.grads(), summed.as_slice());
    }

    #[test]
    fn row_aggregator_reuses_capacity_across_steps() {
        let mut agg = RowAggregator::new();
        agg.begin(3);
        for id in 0..32u32 {
            agg.add(id, &[1.0, 2.0, 3.0]);
        }
        let cap_rows = agg.rows.capacity();
        let cap_grads = agg.grads.capacity();
        for _ in 0..5 {
            agg.begin(3);
            for id in 0..32u32 {
                agg.add(id % 8, &[1.0, 2.0, 3.0]);
            }
            assert_eq!(agg.rows().len(), 8);
            assert_eq!(agg.grads()[0], 4.0); // id 0 hit 4 times
        }
        assert_eq!(agg.rows.capacity(), cap_rows);
        assert_eq!(agg.grads.capacity(), cap_grads);
    }

    #[test]
    fn report_json_shape() {
        let r = TrainReport {
            sampler: "rff".into(),
            history: vec![EvalPoint {
                step: 10,
                epoch: 0.5,
                train_loss: 2.0,
                eval_loss: 2.1,
                metric: 8.2,
            }],
            final_metric: 8.2,
            final_eval_loss: 2.1,
            steps_run: 10,
            wall_seconds: 1.0,
            metrics: Json::Null,
        };
        let j = r.to_json();
        assert_eq!(j.at(&["history", "0", "step"]).unwrap().as_i64(), Some(10));
        assert!(r.curve().contains("(10, 8.20)"));
    }
}
