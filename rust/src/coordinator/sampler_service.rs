//! Sampling service: builds the configured [`Sampler`] and packages each
//! step's negative draw into the tensors the loss executable expects —
//! the logit adjustment `log(m·q)` (paper eq. 5) and the accidental-hit
//! mask (a sampled negative equal to an example's target gets its logit
//! pushed to −∞, the standard sampled-softmax correction).

use crate::config::{Config, SamplerKind};
use crate::linalg::{l2_normalize, Matrix};
use crate::rng::Rng;
use crate::sampler::{
    AliasSampler, ExactSoftmaxSampler, GumbelTopKSampler, LogUniformSampler,
    NegativeDraw, QuadraticSampler, RffSampler, Sampler, UniformSampler,
};
use anyhow::{bail, Result};

/// Build a sampler from config. `classes` must hold the *normalized*
/// class embeddings (the kernel samplers assume the paper's normalized
/// regime); `unigram` supplies the prior for [`SamplerKind::Unigram`].
pub fn build_sampler(
    cfg: &Config,
    classes: &Matrix,
    unigram: Option<&[f64]>,
    rng: &mut Rng,
) -> Result<Box<dyn Sampler>> {
    let n = classes.rows();
    let s = &cfg.sampler;
    Ok(match s.kind {
        SamplerKind::Rff => Box::new(RffSampler::with_kind(
            classes,
            s.dim,
            s.nu,
            s.feature_map,
            rng,
        )),
        SamplerKind::Quadratic => {
            // The quadratic map's D = d²+1 makes the full per-node tree
            // cost O(n·d²) floats; above ~2 GB fall back to the bounded
            // two-level bucket sampler (exact for the quadratic kernel).
            let d = classes.cols();
            let dim = d * d + 1;
            let tree_bytes = 2 * n.next_power_of_two() * dim * 4;
            if tree_bytes > 2 << 30 {
                let map =
                    crate::featmap::QuadraticMap::new(d, s.alpha, 1.0);
                Box::new(crate::sampler::BucketKernelSampler::with_map(
                    classes, map, 1024, "quadratic",
                ))
            } else {
                Box::new(QuadraticSampler::new(classes, s.alpha, 1.0))
            }
        }
        SamplerKind::Uniform => Box::new(UniformSampler::new(n)),
        SamplerKind::LogUniform => Box::new(LogUniformSampler::new(n)),
        SamplerKind::Unigram => match unigram {
            Some(w) => Box::new(AliasSampler::new(w)),
            None => bail!("unigram sampler requires a class prior"),
        },
        SamplerKind::Exact => {
            Box::new(ExactSoftmaxSampler::new(classes, cfg.model.tau))
        }
        SamplerKind::Gumbel => {
            Box::new(GumbelTopKSampler::new(classes, cfg.model.tau))
        }
        SamplerKind::Full => {
            bail!("SamplerKind::Full does not use a sampling service")
        }
    })
}

/// One step's packaged negatives.
#[derive(Clone, Debug)]
pub struct NegativePack {
    /// Sampled class ids (shared across the batch), length m.
    pub ids: Vec<u32>,
    /// `log(m·q_i)` adjustments, length m.
    pub adjust: Vec<f32>,
    /// Accidental-hit mask, `batch × m` (1 = keep, 0 = mask out).
    pub mask: Vec<f32>,
    /// Count of masked (accidental-hit) entries, for metrics.
    pub accidental_hits: usize,
}

/// Wraps a sampler with query normalization, packaging and class-update
/// propagation. Owns the per-run RNG stream for sampling.
pub struct SamplerService {
    sampler: Box<dyn Sampler>,
    pub m: usize,
    rng: Rng,
}

impl SamplerService {
    pub fn new(sampler: Box<dyn Sampler>, m: usize, rng: Rng) -> Self {
        assert!(m > 0);
        Self { sampler, m, rng }
    }

    pub fn name(&self) -> &'static str {
        self.sampler.name()
    }

    pub fn num_classes(&self) -> usize {
        self.sampler.num_classes()
    }

    /// Draw the step's shared negatives for query `h` (any scale; it is
    /// normalized here) and package adjustments + masks against the
    /// batch's targets.
    pub fn draw(&mut self, h: &[f32], targets: &[u32]) -> NegativePack {
        let mut q = h.to_vec();
        l2_normalize(&mut q);
        let draw: NegativeDraw = self.sampler.sample(&q, self.m, &mut self.rng);
        self.package(draw, targets)
    }

    fn package(&self, draw: NegativeDraw, targets: &[u32]) -> NegativePack {
        let m = draw.ids.len();
        let log_m = (m as f64).ln();
        let adjust: Vec<f32> = draw
            .probs
            .iter()
            .map(|&p| (log_m + p.max(f64::MIN_POSITIVE).ln()) as f32)
            .collect();
        let mut mask = vec![1.0f32; targets.len() * m];
        let mut hits = 0usize;
        for (b, &t) in targets.iter().enumerate() {
            for (j, &id) in draw.ids.iter().enumerate() {
                if id == t {
                    mask[b * m + j] = 0.0;
                    hits += 1;
                }
            }
        }
        NegativePack { ids: draw.ids, adjust, mask, accidental_hits: hits }
    }

    /// Propagate an updated class embedding (normalized here) into the
    /// sampler's structure — `O(D log n)` for the kernel tree.
    pub fn update_class(&mut self, class: usize, embedding: &[f32]) {
        let mut e = embedding.to_vec();
        l2_normalize(&mut e);
        self.sampler.update_class(class, &e);
    }

    /// Direct access for diagnostics (bias harness, tests).
    pub fn sampler(&self) -> &dyn Sampler {
        self.sampler.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;

    fn service(n: usize, m: usize) -> SamplerService {
        SamplerService::new(
            Box::new(UniformSampler::new(n)),
            m,
            Rng::seeded(1),
        )
    }

    #[test]
    fn adjustment_is_log_mq() {
        let mut s = service(100, 10);
        let h = vec![1.0f32; 4];
        let pack = s.draw(&h, &[0]);
        // uniform q = 1/100, m = 10 ⇒ log(10/100) = log(0.1).
        for &a in &pack.adjust {
            assert!((a - (0.1f32).ln()).abs() < 1e-5, "adjust {a}");
        }
    }

    #[test]
    fn mask_flags_accidental_hits() {
        let mut s = service(4, 50);
        let h = vec![1.0f32; 2];
        // With n=4 and m=50, targets will certainly collide.
        let pack = s.draw(&h, &[2, 3]);
        assert!(pack.accidental_hits > 0);
        for (b, &t) in [2u32, 3u32].iter().enumerate() {
            for (j, &id) in pack.ids.iter().enumerate() {
                let want = if id == t { 0.0 } else { 1.0 };
                assert_eq!(pack.mask[b * 50 + j], want);
            }
        }
    }

    #[test]
    fn build_sampler_covers_kinds() {
        let mut rng = Rng::seeded(2);
        let classes = Matrix::randn(&mut rng, 20, 8).l2_normalized_rows();
        let mut cfg = Config::default();
        cfg.model.num_classes = 20;
        cfg.sampler.dim = 16;
        cfg.sampler.num_negatives = 5;
        let prior: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        for kind in ["rff", "quadratic", "uniform", "loguniform", "unigram", "exact", "gumbel"] {
            cfg.sampler.kind = SamplerKind::parse(kind).unwrap();
            let s = build_sampler(&cfg, &classes, Some(&prior), &mut rng)
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(s.num_classes(), 20, "{kind}");
            let h = unit_vector(&mut rng, 8);
            let draw = s.sample(&h, 5, &mut rng);
            assert_eq!(draw.len(), 5, "{kind}");
        }
        cfg.sampler.kind = SamplerKind::Full;
        assert!(build_sampler(&cfg, &classes, None, &mut rng).is_err());
    }

    #[test]
    fn update_class_propagates() {
        let mut rng = Rng::seeded(3);
        let classes = Matrix::randn(&mut rng, 10, 4).l2_normalized_rows();
        let sampler = Box::new(ExactSoftmaxSampler::new(&classes, 8.0));
        let mut svc = SamplerService::new(sampler, 3, Rng::seeded(4));
        let h = unit_vector(&mut rng, 4);
        let before = svc.sampler().probability(&h, 1);
        svc.update_class(1, &h);
        assert!(svc.sampler().probability(&h, 1) > before);
    }
}
