//! Sampling service: builds the configured [`Sampler`] and packages each
//! step's negative draw into the tensors the loss executable expects —
//! the logit adjustment `log(m·q)` (paper eq. 5) and the accidental-hit
//! mask (a sampled negative equal to an example's target gets its logit
//! pushed to −∞, the standard sampled-softmax correction).

use crate::config::{Config, FeatureMapKind, SamplerKind};
use crate::featmap::{OrfMap, RffMap, SorfMap};
use crate::linalg::{l2_normalize, Matrix};
use crate::rng::Rng;
use crate::sampler::{
    AliasSampler, ExactSoftmaxSampler, GumbelTopKSampler, KernelTree,
    LogUniformSampler, NegativeDraw, QuadraticSampler, RffSampler, Sampler,
    ShardedKernelSampler, UniformSampler,
};
use crate::admin::{AdminError, AdminOp, AdminResponse, AdminSurface};
use crate::serving::{DoubleBufferedSampler, ServingStats};
use anyhow::{bail, Result};

/// Build a sampler from config. `classes` must hold the *normalized*
/// class embeddings (the kernel samplers assume the paper's normalized
/// regime); `unigram` supplies the prior for [`SamplerKind::Unigram`].
pub fn build_sampler(
    cfg: &Config,
    classes: &Matrix,
    unigram: Option<&[f64]>,
    rng: &mut Rng,
) -> Result<Box<dyn Sampler>> {
    let n = classes.rows();
    let s = &cfg.sampler;
    Ok(match s.kind {
        // `sampler.shards > 1` routes RF-softmax onto the two-level
        // sharded tree: same distribution family, parallel batched
        // updates across disjoint shards. `serving.double_buffer` forces
        // the sharded representation too (1 shard when unsharded was
        // requested): its serving fork is an allocation-level exact
        // clone, so the double buffer costs a memcpy instead of an
        // O(n·cost(φ)) tree rebuild and keeps draw streams exact.
        SamplerKind::Rff if s.shards > 1 || cfg.serving.double_buffer => {
            let d = classes.cols();
            let shards = s.shards.max(1);
            let multi = s.shards > 1;
            // `sampler.rebalance` arms retire-skew redistribution on the
            // sharded representation (a no-op until classes churn);
            // `sampler.max_capacity` pre-reserves shard-tree padding and
            // `sampler.quantize` picks the class-copy precision.
            match s.feature_map {
                FeatureMapKind::Rff => {
                    let mut sk = ShardedKernelSampler::with_map_opts(
                        classes,
                        RffMap::new(d, s.dim, s.nu, rng),
                        shards,
                        if multi { "rff-sharded" } else { "rff" },
                        s.max_capacity,
                        s.quantize,
                    );
                    sk.set_rebalance_threshold(s.rebalance);
                    Box::new(sk)
                }
                FeatureMapKind::Orf => {
                    let mut sk = ShardedKernelSampler::with_map_opts(
                        classes,
                        OrfMap::new(d, s.dim, s.nu, rng),
                        shards,
                        if multi { "rff-orf-sharded" } else { "rff-orf" },
                        s.max_capacity,
                        s.quantize,
                    );
                    sk.set_rebalance_threshold(s.rebalance);
                    Box::new(sk)
                }
                FeatureMapKind::Sorf => {
                    let mut sk = ShardedKernelSampler::with_map_opts(
                        classes,
                        SorfMap::new(d, s.dim, s.nu, rng),
                        shards,
                        if multi { "rff-sorf-sharded" } else { "rff-sorf" },
                        s.max_capacity,
                        s.quantize,
                    );
                    sk.set_rebalance_threshold(s.rebalance);
                    Box::new(sk)
                }
            }
        }
        SamplerKind::Rff => Box::new(RffSampler::with_kind_opts(
            classes,
            s.dim,
            s.nu,
            s.feature_map,
            rng,
            s.max_capacity,
            s.quantize,
        )),
        SamplerKind::Quadratic => {
            // The quadratic map's D = d²+1 makes the full per-node tree
            // cost O(n·d²) floats; above ~2 GB fall back to the bounded
            // two-level bucket sampler (exact for the quadratic kernel).
            // Sharding does not reduce the O(n·D) node sums, so the
            // memory fallback takes priority over `sampler.shards`. The
            // estimate comes from the tree's own accounting (plus the
            // sampler's n×d class copy), so the threshold tracks the
            // actual storage type instead of a hardcoded element size.
            // Double-buffered serving keeps two full sampler copies
            // alive (published snapshot + shadow) and holds a third
            // transiently while forking at construction, so the budget
            // is charged per copy. (The bucket fallback does not support
            // serving forks; the trainers' `new_auto` degrades it to
            // synchronous updates with a warning.) The estimate is taken
            // at the planned **capacity** (`sampler.max_capacity`), not
            // just today's class count: capacity doubling means a tree
            // that grows to `max_capacity` classes occupies exactly what
            // a tree built at that size would, so the fallback decision
            // stays correct after runtime growth instead of being made
            // against a universe about to be outgrown.
            let d = classes.cols();
            let dim = d * d + 1;
            let plan_n = n.max(s.max_capacity);
            // The class-copy term honors `sampler.quantize` (f16 halves,
            // i8 quarters plus one f32 scale per row).
            let class_bytes = match s.quantize {
                crate::linalg::QuantizeKind::None => plan_n * d * 4,
                crate::linalg::QuantizeKind::F16 => plan_n * d * 2,
                crate::linalg::QuantizeKind::I8 => plan_n * d + plan_n * 4,
            };
            let per_copy = KernelTree::estimate_bytes(plan_n, dim) + class_bytes;
            let copies = if cfg.serving.double_buffer { 3 } else { 1 };
            let tree_bytes = per_copy * copies;
            if tree_bytes > 2 << 30 {
                let map =
                    crate::featmap::QuadraticMap::new(d, s.alpha, 1.0);
                Box::new(crate::sampler::BucketKernelSampler::with_map(
                    classes, map, 1024, "quadratic",
                ))
            } else if s.shards > 1 || cfg.serving.double_buffer {
                // Same serving rationale as the Rff arm: the sharded
                // representation's fork is a memcpy clone, so the double
                // buffer skips a second O(n·d²) tree rebuild.
                let mut sk = ShardedKernelSampler::with_map_opts(
                    classes,
                    crate::featmap::QuadraticMap::new(d, s.alpha, 1.0),
                    s.shards.max(1),
                    if s.shards > 1 { "quadratic-sharded" } else { "quadratic" },
                    s.max_capacity,
                    s.quantize,
                );
                sk.set_rebalance_threshold(s.rebalance);
                Box::new(sk)
            } else {
                Box::new(QuadraticSampler::new_opts(
                    classes,
                    s.alpha,
                    1.0,
                    s.max_capacity,
                    s.quantize,
                ))
            }
        }
        SamplerKind::Uniform => Box::new(UniformSampler::new(n)),
        SamplerKind::LogUniform => Box::new(LogUniformSampler::new(n)),
        SamplerKind::Unigram => match unigram {
            Some(w) => Box::new(AliasSampler::new(w)),
            None => bail!("unigram sampler requires a class prior"),
        },
        SamplerKind::Exact => {
            Box::new(ExactSoftmaxSampler::new(classes, cfg.model.tau))
        }
        SamplerKind::Gumbel => {
            Box::new(GumbelTopKSampler::new(classes, cfg.model.tau))
        }
        SamplerKind::Full => {
            bail!("SamplerKind::Full does not use a sampling service")
        }
    })
}

/// One step's packaged negatives.
#[derive(Clone, Debug)]
pub struct NegativePack {
    /// Sampled class ids (shared across the batch), length m.
    pub ids: Vec<u32>,
    /// `log(m·q_i)` adjustments, length m.
    pub adjust: Vec<f32>,
    /// Accidental-hit mask, `batch × m` (1 = keep, 0 = mask out).
    pub mask: Vec<f32>,
    /// Count of masked (accidental-hit) entries, for metrics.
    pub accidental_hits: usize,
}

/// Wraps a sampler with query normalization, packaging and class-update
/// propagation. Owns the per-run RNG stream for sampling.
///
/// Two backends share the same API and serve the same distribution (the
/// draw *streams* also match whenever the sampler's `fork` is an exact
/// clone — sharded kernel trees, static samplers — while unsharded
/// kernel samplers fork onto a 1-shard sharded tree that consumes RNG
/// differently):
///
/// * **direct** ([`SamplerService::new`]): the sampler is owned inline
///   and `update_classes` applies synchronously (the single-threaded
///   reference path);
/// * **double-buffered** ([`SamplerService::new_double_buffered`]):
///   draws run against a pinned [`crate::serving`] snapshot,
///   `update_classes` stages into the server's shadow on a writer
///   thread (overlapping the caller's next phase), and the snapshot
///   swap is forced at the next draw — so no draw ever sees a stale
///   epoch.
pub struct SamplerService {
    backend: Backend,
    pub m: usize,
    rng: Rng,
    /// Reusable normalized-query scratch: `draw_batch` copies the owner
    /// rows here and normalizes in place instead of cloning the full
    /// query matrix every step.
    scratch: Matrix,
}

enum Backend {
    Direct(Box<dyn Sampler>),
    Served(DoubleBufferedSampler),
}

impl SamplerService {
    pub fn new(sampler: Box<dyn Sampler>, m: usize, rng: Rng) -> Self {
        assert!(m > 0);
        Self {
            backend: Backend::Direct(sampler),
            m,
            rng,
            scratch: Matrix::zeros(0, 0),
        }
    }

    /// Double-buffered serving mode (ROADMAP: async double-buffered tree
    /// updates). Fails if the sampler does not support serving forks.
    pub fn new_double_buffered(
        sampler: Box<dyn Sampler>,
        m: usize,
        rng: Rng,
    ) -> Result<Self> {
        assert!(m > 0);
        let served = DoubleBufferedSampler::new(sampler.as_ref())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "sampler '{}' does not support serving forks \
                     (serving.double_buffer)",
                    sampler.name()
                )
            })?;
        Ok(Self {
            backend: Backend::Served(served),
            m,
            rng,
            scratch: Matrix::zeros(0, 0),
        })
    }

    /// The trainers' entry point now that `serving.double_buffer`
    /// defaults to on: double-buffered when requested *and* the sampler
    /// supports serving forks, synchronous otherwise — so a default
    /// config still trains samplers without a fork (the quadratic bucket
    /// memory fallback) instead of failing at construction; the
    /// downgrade is reported once on stderr.
    pub fn new_auto(
        sampler: Box<dyn Sampler>,
        m: usize,
        rng: Rng,
        double_buffer: bool,
    ) -> Self {
        assert!(m > 0);
        if double_buffer {
            if let Some(served) = DoubleBufferedSampler::new(sampler.as_ref()) {
                return Self {
                    backend: Backend::Served(served),
                    m,
                    rng,
                    scratch: Matrix::zeros(0, 0),
                };
            }
            eprintln!(
                "serving.double_buffer: sampler '{}' does not support \
                 serving forks; falling back to synchronous updates",
                sampler.name()
            );
        }
        Self::new(sampler, m, rng)
    }

    pub fn name(&self) -> &'static str {
        self.sampler().name()
    }

    pub fn num_classes(&self) -> usize {
        self.sampler().num_classes()
    }

    /// Whether updates are double-buffered through the serving layer.
    pub fn is_double_buffered(&self) -> bool {
        matches!(self.backend, Backend::Served(_))
    }

    /// Serving counters (double-buffered mode only).
    pub fn serving_stats(&self) -> Option<ServingStats> {
        match &self.backend {
            Backend::Direct(_) => None,
            Backend::Served(db) => Some(db.stats()),
        }
    }

    /// Fold the serving counters into a run's metrics (no-op in direct
    /// mode) — shared by both trainers so the metric names can't drift.
    pub fn record_serving_metrics(&self, metrics: &mut crate::metrics::Metrics) {
        if let Some(st) = self.serving_stats() {
            metrics.incr("serving_publishes", st.publishes);
            metrics.incr("serving_swap_stalls", st.swap_stalls);
            // Non-overlapped remainder of the staged tree refreshes.
            metrics.record_duration(
                "serving_publish_wait",
                std::time::Duration::from_nanos(st.publish_wait_ns),
            );
        }
    }

    /// Step boundary for the served backend: make sure every staged
    /// update is published before the next draw. No-op in direct mode or
    /// when nothing was staged.
    fn sync_serving(&mut self) {
        if let Backend::Served(db) = &mut self.backend {
            db.sync();
        }
    }

    /// Draw the step's shared negatives for query `h` (any scale; it is
    /// normalized here) and package adjustments + masks against the
    /// batch's targets.
    pub fn draw(&mut self, h: &[f32], targets: &[u32]) -> NegativePack {
        self.sync_serving();
        let mut q = h.to_vec();
        l2_normalize(&mut q);
        let draw: NegativeDraw = match &self.backend {
            Backend::Direct(s) => s.sample(&q, self.m, &mut self.rng),
            Backend::Served(db) => db.sampler().sample(&q, self.m, &mut self.rng),
        };
        self.package(draw, targets)
    }

    /// Batch-first draw: rows of `h_rows` form the step's query pool
    /// (normally one row per example; any scale — rows are normalized
    /// here), `targets` the batch's target list for masking. One
    /// [`Sampler::sample_batch_shared`] call serves the whole step: each
    /// of the `m` shared negative slots is owned round-robin by one
    /// query row and drawn *unconditioned* from `q(· | h_owner)` with
    /// its exact probability. No target is excluded from the proposal —
    /// the full support is what keeps the eq.-5 partition estimate
    /// unbiased for every example in the batch (a slot conditioned on
    /// one example's target would silently drop that class's mass from
    /// everyone else's estimate); collisions with any example's target
    /// are handled by the accidental-hit mask exactly as in the classic
    /// shared-negative contract. When the pool has more than `m` rows,
    /// only the first `m` serve as slot owners so no drawn walk is
    /// wasted; a 1-row pool (e.g. stale-sampling mode) degenerates to
    /// the classic single-query shared draw.
    pub fn draw_batch(&mut self, h_rows: &Matrix, targets: &[u32]) -> NegativePack {
        let bsz = h_rows.rows();
        assert!(bsz > 0, "draw_batch: empty query pool");
        assert!(!targets.is_empty(), "draw_batch: empty targets");
        self.sync_serving();
        let owners = bsz.min(self.m).max(1);
        let d = h_rows.cols();
        // Normalize the owner rows into the reusable scratch matrix (no
        // per-step clone of the full query matrix).
        if self.scratch.rows() != owners || self.scratch.cols() != d {
            self.scratch = Matrix::zeros(owners, d);
        }
        for b in 0..owners {
            self.scratch.row_mut(b).copy_from_slice(h_rows.row(b));
        }
        self.scratch.normalize_rows_in_place();
        let per_owner = self.m.div_ceil(owners);
        let batch = match &self.backend {
            Backend::Direct(s) => {
                s.sample_batch_shared(&self.scratch, per_owner, &mut self.rng)
            }
            Backend::Served(db) => db.sampler().sample_batch_shared(
                &self.scratch,
                per_owner,
                &mut self.rng,
            ),
        };
        // Interleave slot ownership draw-index-major so truncation to m
        // keeps owner coverage balanced.
        let mut ids = Vec::with_capacity(self.m);
        let mut probs = Vec::with_capacity(self.m);
        'fill: for k in 0..per_owner {
            for d in &batch.draws {
                if ids.len() == self.m {
                    break 'fill;
                }
                ids.push(d.ids[k]);
                probs.push(d.probs[k]);
            }
        }
        self.package(NegativeDraw { ids, probs }, targets)
    }

    fn package(&self, draw: NegativeDraw, targets: &[u32]) -> NegativePack {
        let m = draw.ids.len();
        let log_m = (m as f64).ln();
        let adjust: Vec<f32> = draw
            .probs
            .iter()
            .map(|&p| (log_m + p.max(f64::MIN_POSITIVE).ln()) as f32)
            .collect();
        let mut mask = vec![1.0f32; targets.len() * m];
        let mut hits = 0usize;
        for (b, &t) in targets.iter().enumerate() {
            for (j, &id) in draw.ids.iter().enumerate() {
                if id == t {
                    mask[b * m + j] = 0.0;
                    hits += 1;
                }
            }
        }
        NegativePack { ids: draw.ids, adjust, mask, accidental_hits: hits }
    }

    /// Propagate an updated class embedding (normalized here) into the
    /// sampler's structure — `O(D log n)` for the kernel tree. In
    /// double-buffered mode the update is staged asynchronously and
    /// becomes visible at the next draw.
    pub fn update_class(&mut self, class: usize, embedding: &[f32]) {
        let mut e = embedding.to_vec();
        l2_normalize(&mut e);
        match &mut self.backend {
            Backend::Direct(s) => s.update_class(class, &e),
            Backend::Served(db) => {
                let d = e.len();
                db.stage_updates(vec![class as u32], Matrix::from_vec(1, d, e));
            }
        }
    }

    /// Batched propagation of one step's touched classes: rows of
    /// `embeddings` (normalized here) replace classes `rows[k]`. Kernel
    /// samplers recompute φ for the whole batch in two gemms; the sharded
    /// sampler additionally applies disjoint shards in parallel. Ids must
    /// be unique (gradient aggregation guarantees this). In
    /// double-buffered mode the batch is staged into the serving shadow
    /// and the tree refresh overlaps the caller's next phase; the swap
    /// lands before the next draw.
    pub fn update_classes(&mut self, rows: &[usize], embeddings: &Matrix) {
        assert_eq!(rows.len(), embeddings.rows(), "update_classes: mismatch");
        if rows.is_empty() {
            return;
        }
        let mut normed = embeddings.clone();
        normed.normalize_rows_in_place();
        let ids: Vec<u32> = rows.iter().map(|&r| r as u32).collect();
        match &mut self.backend {
            Backend::Direct(s) => s.update_classes(&ids, &normed),
            Backend::Served(db) => db.stage_updates(ids, normed),
        }
    }

    /// Grow the class universe: deprecated shim over
    /// [`AdminSurface::admin_add`], kept one release for embedders.
    #[deprecated(note = "use AdminSurface::admin_add (typed ops/errors)")]
    pub fn extend_vocab(&mut self, embeddings: &Matrix) -> Result<Vec<u32>> {
        self.admin_add(embeddings.clone())
            .map(|(ids, _epoch)| ids)
            .map_err(|e| anyhow::anyhow!("extend_vocab: {e}"))
    }

    /// Retire live classes: deprecated shim over
    /// [`AdminSurface::admin_retire`], kept one release for embedders.
    #[deprecated(note = "use AdminSurface::admin_retire (typed ops/errors)")]
    pub fn retire_classes(&mut self, ids: &[u32]) -> Result<()> {
        self.admin_retire(ids.to_vec())
            .map(|_epoch| ())
            .map_err(|e| anyhow::anyhow!("retire_classes: {e}"))
    }

    /// Direct access for diagnostics (bias harness, tests). In
    /// double-buffered mode this is the *pinned snapshot* — stable until
    /// the next draw publishes staged updates.
    pub fn sampler(&self) -> &dyn Sampler {
        match &self.backend {
            Backend::Direct(s) => s.as_ref(),
            Backend::Served(db) => db.sampler(),
        }
    }
}

/// The coordinator's impl of the unified admin API. Direct mode applies
/// synchronously (there is no epoch versioning — responses report epoch
/// `0`); double-buffered mode delegates to the
/// [`DoubleBufferedSampler`] surface, so churn and restores become
/// visible at the next draw as one epoch swap. Class embeddings are
/// row-normalized here (the kernel samplers assume the paper's
/// normalized regime).
impl AdminSurface for SamplerService {
    fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError> {
        match op {
            AdminOp::AddClasses { embeddings } => {
                let mut normed = embeddings;
                normed.normalize_rows_in_place();
                match &mut self.backend {
                    Backend::Direct(s) => {
                        let ids = s.add_classes(&normed)?;
                        Ok(AdminResponse::Added { ids, epoch: 0 })
                    }
                    Backend::Served(db) => {
                        db.admin(AdminOp::AddClasses { embeddings: normed })
                    }
                }
            }
            AdminOp::RetireClasses { ids } => match &mut self.backend {
                Backend::Direct(s) => {
                    s.retire_classes(&ids)?;
                    Ok(AdminResponse::Retired { epoch: 0 })
                }
                Backend::Served(db) => {
                    db.admin(AdminOp::RetireClasses { ids })
                }
            },
            AdminOp::Snapshot => match &mut self.backend {
                Backend::Direct(s) => {
                    let state = s
                        .snapshot_state()
                        .ok_or(AdminError::Unsupported("direct sampler kind"))?;
                    Ok(AdminResponse::Snapshot {
                        snapshot: Box::new(crate::snapshot::Snapshot {
                            epoch: 0,
                            state,
                        }),
                    })
                }
                Backend::Served(db) => db.admin(AdminOp::Snapshot),
            },
            AdminOp::Restore { state } => match &mut self.backend {
                Backend::Direct(s) => {
                    s.restore_state(&state)?;
                    Ok(AdminResponse::Restored { epoch: 0 })
                }
                Backend::Served(db) => db.admin(AdminOp::Restore { state }),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;

    fn service(n: usize, m: usize) -> SamplerService {
        SamplerService::new(
            Box::new(UniformSampler::new(n)),
            m,
            Rng::seeded(1),
        )
    }

    #[test]
    fn adjustment_is_log_mq() {
        let mut s = service(100, 10);
        let h = vec![1.0f32; 4];
        let pack = s.draw(&h, &[0]);
        // uniform q = 1/100, m = 10 ⇒ log(10/100) = log(0.1).
        for &a in &pack.adjust {
            assert!((a - (0.1f32).ln()).abs() < 1e-5, "adjust {a}");
        }
    }

    #[test]
    fn mask_flags_accidental_hits() {
        let mut s = service(4, 50);
        let h = vec![1.0f32; 2];
        // With n=4 and m=50, targets will certainly collide.
        let pack = s.draw(&h, &[2, 3]);
        assert!(pack.accidental_hits > 0);
        for (b, &t) in [2u32, 3u32].iter().enumerate() {
            for (j, &id) in pack.ids.iter().enumerate() {
                let want = if id == t { 0.0 } else { 1.0 };
                assert_eq!(pack.mask[b * 50 + j], want);
            }
        }
    }

    #[test]
    fn build_sampler_covers_kinds() {
        let mut rng = Rng::seeded(2);
        let classes = Matrix::randn(&mut rng, 20, 8).l2_normalized_rows();
        let mut cfg = Config::default();
        cfg.model.num_classes = 20;
        cfg.sampler.dim = 16;
        cfg.sampler.num_negatives = 5;
        let prior: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        for kind in ["rff", "quadratic", "uniform", "loguniform", "unigram", "exact", "gumbel"] {
            cfg.sampler.kind = SamplerKind::parse(kind).unwrap();
            let s = build_sampler(&cfg, &classes, Some(&prior), &mut rng)
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(s.num_classes(), 20, "{kind}");
            let h = unit_vector(&mut rng, 8);
            let draw = s.sample(&h, 5, &mut rng);
            assert_eq!(draw.len(), 5, "{kind}");
        }
        cfg.sampler.kind = SamplerKind::Full;
        assert!(build_sampler(&cfg, &classes, None, &mut rng).is_err());
    }

    #[test]
    fn build_sampler_routes_shards_to_sharded_tree() {
        let mut rng = Rng::seeded(5);
        let classes = Matrix::randn(&mut rng, 32, 8).l2_normalized_rows();
        let mut cfg = Config::default();
        cfg.model.num_classes = 32;
        cfg.sampler.dim = 16;
        cfg.sampler.num_negatives = 5;
        cfg.sampler.shards = 4;
        let s = build_sampler(&cfg, &classes, None, &mut rng).unwrap();
        assert_eq!(s.name(), "rff-sharded");
        assert_eq!(s.num_classes(), 32);
        let h = unit_vector(&mut rng, 8);
        let total: f64 = (0..32).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
    }

    #[test]
    fn build_sampler_threads_quantize_and_capacity() {
        let mut rng = Rng::seeded(8);
        let classes = Matrix::randn(&mut rng, 32, 8).l2_normalized_rows();
        let mut cfg = Config::default();
        cfg.model.num_classes = 32;
        cfg.sampler.dim = 16;
        cfg.sampler.num_negatives = 5;
        cfg.sampler.shards = 4;
        cfg.sampler.max_capacity = 64;
        cfg.set("sampler.quantize", "f16").unwrap();
        let s = build_sampler(&cfg, &classes, None, &mut rng).unwrap();
        assert_eq!(s.name(), "rff-sharded");
        let h = unit_vector(&mut rng, 8);
        let total: f64 = (0..32).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
    }

    #[test]
    fn draw_batch_packages_shared_negatives() {
        let mut svc = service(50, 12);
        let mut h = Matrix::zeros(5, 4);
        for b in 0..5 {
            h.row_mut(b).copy_from_slice(&[1.0, b as f32, 0.0, -1.0]);
        }
        let targets = [0u32, 1, 2, 3, 4];
        let pack = svc.draw_batch(&h, &targets);
        assert_eq!(pack.ids.len(), 12);
        assert_eq!(pack.adjust.len(), 12);
        assert_eq!(pack.mask.len(), 5 * 12);
        assert!(pack.adjust.iter().all(|a| a.is_finite()));
        // Slots are drawn unconditioned; collisions with any example's
        // target are masked, exactly as in the shared-draw contract.
        for (b, &t) in targets.iter().enumerate() {
            for (j, &id) in pack.ids.iter().enumerate() {
                let want = if id == t { 0.0 } else { 1.0 };
                assert_eq!(pack.mask[b * 12 + j], want);
            }
        }
        // Uniform sampler, unconditioned: every slot's q is 1/n ⇒
        // adjust is log(m·q) = log(12/50).
        for &a in &pack.adjust {
            let want = (12.0f32 / 50.0).ln();
            assert!((a - want).abs() < 1e-5, "adjust {a} vs {want}");
        }
    }

    #[test]
    fn draw_batch_caps_owners_at_m() {
        // batch 30 > m 4: only the first 4 rows serve as slot owners;
        // the pack still has exactly m slots and a full batch×m mask.
        let mut svc = service(20, 4);
        let h = Matrix::zeros(30, 4);
        let targets: Vec<u32> = (0..30).map(|b| (b % 20) as u32).collect();
        let pack = svc.draw_batch(&h, &targets);
        assert_eq!(pack.ids.len(), 4);
        assert_eq!(pack.mask.len(), 30 * 4);
        for &a in &pack.adjust {
            let want = (4.0f32 / 20.0).ln();
            assert!((a - want).abs() < 1e-5, "adjust {a} vs {want}");
        }
    }

    #[test]
    fn batched_update_classes_propagates() {
        let mut rng = Rng::seeded(6);
        let classes = Matrix::randn(&mut rng, 10, 4).l2_normalized_rows();
        let sampler = Box::new(ExactSoftmaxSampler::new(&classes, 8.0));
        let mut svc = SamplerService::new(sampler, 3, Rng::seeded(7));
        let h = unit_vector(&mut rng, 4);
        let before = svc.sampler().probability(&h, 2);
        let mut emb = Matrix::zeros(2, 4);
        emb.row_mut(0).copy_from_slice(&h);
        let other = unit_vector(&mut rng, 4);
        emb.row_mut(1).copy_from_slice(&other);
        svc.update_classes(&[2, 7], &emb);
        assert!(svc.sampler().probability(&h, 2) > before);
    }

    #[test]
    fn double_buffered_service_matches_direct_stream_for_sharded_rff() {
        // The sharded sampler's fork is stream-exact, so with identical
        // seeds the served backend must reproduce the direct backend's
        // draws bit-for-bit — any stale-epoch read (an update staged but
        // not published before the next draw) would diverge the ids.
        let mut rng = Rng::seeded(900);
        let d = 8;
        let classes = Matrix::randn(&mut rng, 64, d).l2_normalized_rows();
        let build = || {
            let map = crate::featmap::RffMap::new(d, 32, 2.0, &mut Rng::seeded(901));
            Box::new(ShardedKernelSampler::with_map(
                &classes, map, 4, "rff-sharded",
            )) as Box<dyn Sampler>
        };
        let m = 10;
        let mut direct = SamplerService::new(build(), m, Rng::seeded(902));
        let mut served =
            SamplerService::new_double_buffered(build(), m, Rng::seeded(902))
                .unwrap();
        assert!(served.is_double_buffered());
        assert!(!direct.is_double_buffered());

        let mut data_rng = Rng::seeded(903);
        for step in 1..=5u64 {
            let mut h = Matrix::zeros(6, d);
            for b in 0..6 {
                let v = unit_vector(&mut data_rng, d);
                h.row_mut(b).copy_from_slice(&v);
            }
            let targets: Vec<u32> = (0..6).collect();
            let pd = direct.draw_batch(&h, &targets);
            let ps = served.draw_batch(&h, &targets);
            assert_eq!(pd.ids, ps.ids, "step {step}: draw streams diverged");
            assert_eq!(pd.adjust, ps.adjust, "step {step}: adjustments");
            assert_eq!(pd.mask, ps.mask, "step {step}: masks");

            // Stage the same updates into both backends.
            let rows: Vec<usize> = vec![step as usize, 32 + step as usize];
            let mut emb = Matrix::zeros(2, d);
            for r in 0..2 {
                let v = unit_vector(&mut data_rng, d);
                emb.row_mut(r).copy_from_slice(&v);
            }
            direct.update_classes(&rows, &emb);
            served.update_classes(&rows, &emb);
        }
        // One publish per step (each draw after staged updates swaps).
        let final_h = Matrix::zeros(1, d);
        let _ = served.draw_batch(&final_h, &[0]);
        let stats = served.serving_stats().unwrap();
        assert_eq!(stats.publishes, 5);
        assert_eq!(stats.epoch, 5);
        assert_eq!(stats.swap_stalls, 0);
        assert!(direct.serving_stats().is_none());
    }

    #[test]
    fn new_auto_degrades_to_direct_when_fork_unsupported() {
        // A sampler without a serving fork (like the quadratic bucket
        // fallback) must still construct under the double_buffer default
        // — synchronously, not with an error.
        struct NoFork;
        impl Sampler for NoFork {
            fn num_classes(&self) -> usize {
                8
            }
            fn sample(
                &self,
                _h: &[f32],
                m: usize,
                rng: &mut Rng,
            ) -> NegativeDraw {
                let ids: Vec<u32> =
                    (0..m).map(|_| rng.index(8) as u32).collect();
                NegativeDraw { ids, probs: vec![1.0 / 8.0; m] }
            }
            fn probability(&self, _h: &[f32], _class: usize) -> f64 {
                1.0 / 8.0
            }
            fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}
            fn name(&self) -> &'static str {
                "nofork"
            }
        }
        let svc =
            SamplerService::new_auto(Box::new(NoFork), 3, Rng::seeded(1), true);
        assert!(!svc.is_double_buffered(), "fork-less must degrade");
        let svc = SamplerService::new_auto(
            Box::new(UniformSampler::new(8)),
            3,
            Rng::seeded(1),
            true,
        );
        assert!(svc.is_double_buffered(), "forkable + requested must serve");
        let svc = SamplerService::new_auto(
            Box::new(UniformSampler::new(8)),
            3,
            Rng::seeded(1),
            false,
        );
        assert!(!svc.is_double_buffered(), "not requested must stay direct");
    }

    #[test]
    fn draw_batch_reuses_scratch_without_cloning() {
        let mut svc = service(40, 6);
        let mut h = Matrix::zeros(4, 3);
        for b in 0..4 {
            h.row_mut(b).copy_from_slice(&[b as f32 + 1.0, 0.0, 2.0]);
        }
        let targets = [0u32, 1, 2, 3];
        let p1 = svc.draw_batch(&h, &targets);
        assert_eq!(svc.scratch.rows(), 4);
        assert_eq!(svc.scratch.cols(), 3);
        // Scratch rows are the normalized queries.
        for b in 0..4 {
            let n: f32 =
                svc.scratch.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {b} norm {n}");
        }
        // Same-shape second call reuses the buffer; owner-capped call
        // (bsz > m) resizes to m rows.
        let _ = svc.draw_batch(&h, &targets);
        assert_eq!(svc.scratch.rows(), 4);
        let big = Matrix::zeros(20, 3);
        let big_targets: Vec<u32> = (0..20).collect();
        let p2 = svc.draw_batch(&big, &big_targets);
        assert_eq!(svc.scratch.rows(), 6); // owners = min(bsz, m)
        assert_eq!(p1.ids.len(), 6);
        assert_eq!(p2.ids.len(), 6);
        assert_eq!(p2.mask.len(), 20 * 6);
    }

    #[test]
    fn extend_and_retire_through_both_backends() {
        let mut rng = Rng::seeded(950);
        let d = 6;
        let classes = Matrix::randn(&mut rng, 20, d).l2_normalized_rows();
        let build = || {
            let map =
                crate::featmap::RffMap::new(d, 32, 2.0, &mut Rng::seeded(951));
            Box::new(ShardedKernelSampler::with_map(
                &classes, map, 4, "rff-sharded",
            )) as Box<dyn Sampler>
        };
        let mut direct = SamplerService::new(build(), 4, Rng::seeded(952));
        let mut served =
            SamplerService::new_double_buffered(build(), 4, Rng::seeded(952))
                .unwrap();
        let mut grow = Matrix::zeros(3, d);
        for r in 0..3 {
            // Deliberately unnormalized: the service normalizes.
            let mut v = unit_vector(&mut rng, d);
            v.iter_mut().for_each(|x| *x *= 3.0);
            grow.row_mut(r).copy_from_slice(&v);
        }
        let (ids_d, _) = direct.admin_add(grow.clone()).unwrap();
        let (ids_s, _) = served.admin_add(grow.clone()).unwrap();
        assert_eq!(ids_d, vec![20, 21, 22]);
        assert_eq!(ids_d, ids_s);
        direct.admin_retire(vec![1, 21]).unwrap();
        served.admin_retire(vec![1, 21]).unwrap();
        assert_eq!(direct.num_classes(), 23);
        // Direct mode is immediate; served mode lands at the next draw.
        assert_eq!(direct.sampler().live_classes(), 21);
        let h = Matrix::from_vec(1, d, unit_vector(&mut rng, d));
        let _ = served.draw_batch(&h, &[0]);
        assert_eq!(served.num_classes(), 23);
        assert_eq!(served.sampler().live_classes(), 21);
        // Both serve the same (normalized-embedding) distribution.
        let q = unit_vector(&mut rng, d);
        for i in 0..23 {
            let a = direct.sampler().probability(&q, i);
            let b = served.sampler().probability(&q, i);
            assert!(
                (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                "class {i}: direct {a} vs served {b}"
            );
        }
        assert_eq!(direct.sampler().probability(&q, 1), 0.0);
        // Typed error surfaces through the service.
        assert!(matches!(
            direct.admin_retire(vec![1]),
            Err(AdminError::Vocab(_))
        ));
        assert!(served.admin_retire(vec![1]).is_err());
        // The deprecated anyhow shims still answer during the
        // migration window.
        #[allow(deprecated)]
        {
            assert!(direct.retire_classes(&[1]).is_err());
            let one = Matrix::from_vec(1, d, unit_vector(&mut rng, d));
            assert_eq!(direct.extend_vocab(&one).unwrap(), vec![23]);
        }
    }

    #[test]
    fn snapshot_and_restore_through_the_service() {
        let mut rng = Rng::seeded(960);
        let d = 6;
        let classes = Matrix::randn(&mut rng, 24, d).l2_normalized_rows();
        let map =
            crate::featmap::RffMap::new(d, 32, 2.0, &mut Rng::seeded(961));
        let sampler = Box::new(ShardedKernelSampler::with_map(
            &classes, map, 2, "rff-sharded",
        )) as Box<dyn Sampler>;
        let mut svc = SamplerService::new(sampler, 4, Rng::seeded(962));
        svc.admin_retire(vec![7]).unwrap();
        let snap = svc.admin_snapshot().unwrap();
        assert_eq!(snap.state.live_classes(), 23);
        svc.admin_retire(vec![9, 11]).unwrap();
        assert_eq!(svc.sampler().live_classes(), 21);
        let epoch = svc.admin_restore(snap.state).unwrap();
        assert_eq!(epoch, 0, "direct backend has no epoch versioning");
        assert_eq!(svc.sampler().live_classes(), 23);
        let q = unit_vector(&mut rng, d);
        assert!(svc.sampler().probability(&q, 9) > 0.0);
        assert_eq!(svc.sampler().probability(&q, 7), 0.0);
    }

    #[test]
    fn quadratic_memory_estimate_tracks_tree_accounting() {
        // The fallback threshold derives from KernelTree::estimate_bytes;
        // for a buildable size the estimate must equal the real tree.
        let n = 500;
        let d = 8;
        let dim = d * d + 1;
        let tree = KernelTree::new(n, dim, 1e-8);
        assert_eq!(KernelTree::estimate_bytes(n, dim), tree.memory_bytes());
    }

    #[test]
    fn update_class_propagates() {
        let mut rng = Rng::seeded(3);
        let classes = Matrix::randn(&mut rng, 10, 4).l2_normalized_rows();
        let sampler = Box::new(ExactSoftmaxSampler::new(&classes, 8.0));
        let mut svc = SamplerService::new(sampler, 3, Rng::seeded(4));
        let h = unit_vector(&mut rng, 4);
        let before = svc.sampler().probability(&h, 1);
        svc.update_class(1, &h);
        assert!(svc.sampler().probability(&h, 1) > before);
    }
}
