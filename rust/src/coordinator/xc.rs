//! Extreme-classification training driver (AmazonCat-13K / Delicious-200K
//! / WikiLSHTC experiments, paper Table 3).
//!
//! Architecture (mirrors `python/compile/model.py::xc_*`): sparse features
//! → feature-embedding gather (Rust) → weighted sum → L2-normalized h →
//! sampled softmax against the reduced multi-class target. The sampling
//! query h is cheap enough here to compute in Rust directly (no encoder
//! artifact needed).

use super::sampler_service::{build_sampler, SamplerService};
use super::{aggregate_rows, step_cap, EvalPoint, TrainReport};
use crate::config::{Config, SamplerKind};
use crate::data::extreme::{ExtremeDataset, ExtremeParams};
use crate::data::SparseBatch;
use crate::eval::batch_precision_at_k;
use crate::linalg::{axpy_rows, l2_normalize, Matrix};
use crate::metrics::{Ewma, Metrics};
use crate::model::ParamStore;
use crate::optim::Optimizer;
use crate::rng::Rng;
use crate::runtime::{HostTensor, Runtime};
use anyhow::{anyhow, Result};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct XcShapes {
    pub n: usize,
    pub d: usize,
    pub v: usize,
    pub nnz: usize,
    pub batch: usize,
    pub m: usize,
    pub tau: f32,
}

pub struct XcTrainer<'rt> {
    runtime: &'rt Runtime,
    prefix: String,
    cfg: Config,
    pub shapes: XcShapes,
    data: ExtremeDataset,
    params: ParamStore,
    optimizer: Optimizer,
    service: Option<SamplerService>,
    pub metrics: Metrics,
    rng: Rng,
    /// Use the `*_unnorm` artifact variants (§4.2 ablation; FULL only).
    unnormalized: bool,
}

const W: usize = 0; // feature embeddings (v, d)
const CLS: usize = 1; // class embeddings (n, d)

impl<'rt> XcTrainer<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        prefix: &str,
        cfg: Config,
        unnormalized: bool,
    ) -> Result<Self> {
        super::validate_sampler_kind(cfg.sampler.kind)?;
        let meta = runtime
            .manifest()
            .get(&format!("{prefix}_train_sampled"))
            .ok_or_else(|| anyhow!("missing {prefix}_train_sampled"))?;
        let g = |k: &str| -> Result<usize> {
            meta.meta_usize(k)
                .ok_or_else(|| anyhow!("manifest meta missing '{k}'"))
        };
        let shapes = XcShapes {
            n: g("n")?,
            d: g("d")?,
            v: g("v")?,
            nnz: g("nnz")?,
            batch: g("batch")?,
            m: g("m")?,
            tau: meta.meta_f64("tau").ok_or_else(|| anyhow!("meta tau"))? as f32,
        };
        anyhow::ensure!(
            cfg.sampler.kind == SamplerKind::Full
                || cfg.sampler.num_negatives == shapes.m,
            "config m={} but artifact compiled for m={}",
            cfg.sampler.num_negatives,
            shapes.m
        );

        let data = ExtremeDataset::generate(&ExtremeParams {
            num_classes: shapes.n,
            feature_dim: shapes.v,
            latent_dim: cfg.data.latent_dim.max(2),
            nnz: shapes.nnz,
            labels_per_example: cfg.data.labels_per_example,
            train_examples: cfg.data.train_size,
            test_examples: cfg.data.valid_size,
            noise: cfg.data.noise,
            candidates: if shapes.n > 20_000 { 4096 } else { 0 },
            clusters: cfg.data.clusters,
            seed: cfg.data.seed,
        });

        let mut rng = Rng::seeded(cfg.train.seed);
        let mut params = ParamStore::new();
        assert_eq!(
            params.add_randn("w", &[shapes.v, shapes.d], 0.1, &mut rng),
            W
        );
        assert_eq!(
            params.add_randn("cls", &[shapes.n, shapes.d], 0.1, &mut rng),
            CLS
        );

        let service = if cfg.sampler.kind == SamplerKind::Full {
            None
        } else {
            let b = params.get(CLS);
            let normalized = Matrix::from_vec(b.rows(), b.cols(), b.data.clone())
                .l2_normalized_rows();
            let prior = data.class_prior();
            let sampler = build_sampler(&cfg, &normalized, Some(&prior), &mut rng)?;
            let svc_rng = Rng::seeded(cfg.sampler.seed);
            // serving.double_buffer (default on) overlaps tree refresh
            // with the step (see rust/src/serving); distribution-
            // identical to the synchronous path (stream-exact for exact
            // forks). Fork-less samplers degrade to synchronous updates
            // with a warning.
            Some(SamplerService::new_auto(
                sampler,
                shapes.m,
                svc_rng,
                cfg.serving.double_buffer,
            ))
        };

        let optimizer = Optimizer::from_config(&cfg.train);
        Ok(Self {
            runtime,
            prefix: prefix.to_string(),
            cfg,
            shapes,
            data,
            params,
            optimizer,
            service,
            metrics: Metrics::new(),
            rng,
            unnormalized,
        })
    }

    fn artifact(&self, entry: &str) -> String {
        if self.unnormalized && matches!(entry, "train_full" | "scores") {
            format!("{}_{entry}_unnorm", self.prefix)
        } else {
            format!("{}_{entry}", self.prefix)
        }
    }

    fn train_entry(&self) -> String {
        match self.cfg.sampler.kind {
            SamplerKind::Full => self.artifact("train_full"),
            // The absolute-softmax loss ([12]'s pairing for the quadratic
            // kernel) is opt-in; see SamplerConfig::absolute.
            SamplerKind::Quadratic if self.cfg.sampler.absolute => {
                self.artifact("train_sampled_abs")
            }
            _ => self.artifact("train_sampled"),
        }
    }

    fn sampler_name(&self) -> &'static str {
        match &self.service {
            Some(s) => s.name(),
            None => "full",
        }
    }

    /// Grow the label universe mid-run (streaming extreme-classification
    /// deployments gain labels continuously): rows of `embeddings`
    /// become new classes with stable ids extending `0..n`. The CLS
    /// block grows in place (optimizer history preserved), the sampler
    /// tree grows in amortized `O(D log n)` per class, and the sampled
    /// train path keeps working unchanged (its artifacts gather rows —
    /// they are n-independent). PREC@k evaluation keeps ranking the
    /// compiled base label set.
    pub fn extend_vocab(&mut self, embeddings: &Matrix) -> Result<Vec<u32>> {
        super::extend_vocab_impl(
            self.service.as_mut(),
            &mut self.params,
            &mut self.optimizer,
            &mut self.metrics,
            CLS,
            self.shapes.d,
            embeddings,
        )
    }

    /// Retire live labels: permanent holes the sampler never draws again.
    /// See [`super::retire_classes_impl`] for the retired-target
    /// precondition on the data stream.
    pub fn retire_classes(&mut self, ids: &[u32]) -> Result<()> {
        super::retire_classes_impl(self.service.as_mut(), &mut self.metrics, ids)
    }

    /// First `rows` rows of a 2-D block — the compiled artifacts' fixed
    /// shape view of a table that may have grown past it.
    fn block_tensor_rows(&self, id: usize, rows: usize) -> HostTensor {
        super::block_rows_tensor(&self.params, id, rows)
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = Instant::now();
        let total_steps = step_cap()
            .map(|c| c.min(self.cfg.train.steps))
            .unwrap_or(self.cfg.train.steps);
        let bsz = self.shapes.batch;
        let ntrain = self.data.train.len();

        let mut ewma = Ewma::new(0.05);
        let mut history = Vec::new();
        for step in 1..=total_steps {
            // Batch assembly: random with-replacement example draw.
            let idx: Vec<usize> =
                (0..bsz).map(|_| self.rng.index(ntrain)).collect();
            let mut data_rng = self.rng.split();
            let batch = self.data.train_batch(&idx, &mut data_rng);
            let loss = self.step(&batch)?;
            let smooth = ewma.record(loss);
            self.metrics.observe("train_loss", loss);
            self.metrics.incr("steps", 1);

            if step % self.cfg.train.eval_every == 0 || step == total_steps {
                let (p1, p3, p5) = self.evaluate()?;
                history.push(EvalPoint {
                    step,
                    epoch: step as f64 * bsz as f64 / ntrain as f64,
                    train_loss: smooth,
                    eval_loss: smooth,
                    metric: p1,
                });
                self.metrics.observe("prec_at_3", p3);
                self.metrics.observe("prec_at_5", p5);
            }
        }

        if let Some(svc) = &self.service {
            svc.record_serving_metrics(&mut self.metrics);
        }

        let last = history.last().cloned().unwrap_or(EvalPoint {
            step: 0,
            epoch: 0.0,
            train_loss: f64::NAN,
            eval_loss: f64::NAN,
            metric: f64::NAN,
        });
        Ok(TrainReport {
            sampler: self.sampler_name().to_string(),
            history,
            final_metric: last.metric,
            final_eval_loss: last.eval_loss,
            steps_run: total_steps,
            wall_seconds: t0.elapsed().as_secs_f64(),
            metrics: self.metrics.to_json(),
        })
    }

    /// Final PREC@{1,3,5} (the Table-3 row for this sampler).
    pub fn final_precisions(&mut self) -> Result<(f64, f64, f64)> {
        self.evaluate()
    }

    fn step(&mut self, batch: &SparseBatch) -> Result<f64> {
        if self.cfg.sampler.kind == SamplerKind::Full {
            self.step_full(batch)
        } else {
            self.step_sampled(batch)
        }
    }

    /// Per-example input embeddings h, computed Rust-side as the sampling
    /// query matrix (one L2-normalized row per example — no mean-query
    /// collapse; each row is a weighted feature-row sum via
    /// [`axpy_rows`]).
    fn queries_of_batch(&self, batch: &SparseBatch) -> Matrix {
        let d = self.shapes.d;
        let w = self.params.get(W);
        let mut q = Matrix::zeros(batch.batch, d);
        for i in 0..batch.batch {
            let (feats, vals) = batch.feature_row(i);
            let row = q.row_mut(i);
            axpy_rows(&w.data, d, feats, vals, row);
            l2_normalize(row);
        }
        q
    }

    fn step_sampled(&mut self, batch: &SparseBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, nnz, d, m) = (s.batch, s.nnz, s.d, s.m);

        let t_sample = Instant::now();
        let queries = self.queries_of_batch(batch);
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(&queries, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        let t_exec = Instant::now();
        let feat_emb = super::lm::gather_rows(
            &self.params.get(W).data,
            d,
            &batch.features,
        );
        let tgt_emb = super::lm::gather_rows(
            &self.params.get(CLS).data,
            d,
            &batch.targets,
        );
        let neg_emb =
            super::lm::gather_rows(&self.params.get(CLS).data, d, &pack.ids);
        let exe = self.runtime.get(&self.train_entry())?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, nnz, d], feat_emb),
            HostTensor::f32(&[bsz, nnz], batch.values.clone()),
            HostTensor::f32(&[bsz, d], tgt_emb),
            HostTensor::f32(&[m, d], neg_emb),
            HostTensor::f32(&[m], pack.adjust.clone()),
            HostTensor::f32(&[bsz, m], pack.mask.clone()),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        let t_opt = Instant::now();
        let (rows, grads) = aggregate_rows(&batch.features, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(W, &mut param.data, d, &rows, &grads);
        }
        let mut cls_ids: Vec<u32> = batch.targets.clone();
        cls_ids.extend_from_slice(&pack.ids);
        let mut cls_grads: Vec<f32> = outs[2].as_f32().to_vec();
        cls_grads.extend_from_slice(outs[3].as_f32());
        let (crow, cgrads) = aggregate_rows(&cls_ids, &cls_grads, d);
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_rows(CLS, &mut param.data, d, &crow, &cgrads);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // Propagate the step's touched classes as one sharded batch.
        let t_tree = Instant::now();
        let cls_block = self.params.get(CLS);
        let crow_u32: Vec<u32> = crow.iter().map(|&r| r as u32).collect();
        let upd = Matrix::from_vec(
            crow.len(),
            d,
            super::lm::gather_rows(&cls_block.data, d, &crow_u32),
        );
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(&crow, &upd);
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        Ok(loss)
    }

    fn step_full(&mut self, batch: &SparseBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, nnz, d) = (s.batch, s.nnz, s.d);
        let feat_emb = super::lm::gather_rows(
            &self.params.get(W).data,
            d,
            &batch.features,
        );
        let targets: Vec<i32> =
            batch.targets.iter().map(|&t| t as i32).collect();
        let exe = self.runtime.get(&self.artifact("train_full"))?;
        let t_exec = Instant::now();
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, nnz, d], feat_emb),
            HostTensor::f32(&[bsz, nnz], batch.values.clone()),
            self.block_tensor_rows(CLS, self.shapes.n),
            HostTensor::i32(&[bsz], targets),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        let (rows, grads) = aggregate_rows(&batch.features, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(W, &mut param.data, d, &rows, &grads);
        }
        {
            let grad = outs[2].as_f32().to_vec();
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &grad);
        }
        Ok(loss)
    }

    /// PREC@{1,3,5} on the test split via the scores artifact.
    pub fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let s = &self.shapes;
        let (bsz, nnz, d, n) = (s.batch, s.nnz, s.d, s.n);
        let exe = self.runtime.get(&self.artifact("scores"))?;
        let t_eval = Instant::now();
        let mut p1 = 0.0;
        let mut p3 = 0.0;
        let mut p5 = 0.0;
        let mut batches = 0usize;
        let eval_examples = (self.cfg.train.eval_batches * bsz)
            .min(self.data.test.len() / bsz * bsz);
        for chunk in (0..eval_examples).collect::<Vec<_>>().chunks(bsz) {
            if chunk.len() < bsz {
                break;
            }
            let mut features = Vec::with_capacity(bsz * nnz);
            let mut values = Vec::with_capacity(bsz * nnz);
            let mut labels: Vec<Vec<u32>> = Vec::with_capacity(bsz);
            for &i in chunk {
                let ex = &self.data.test[i];
                features.extend_from_slice(&ex.features);
                values.extend_from_slice(&ex.values);
                labels.push(ex.labels.clone());
            }
            let feat_emb =
                super::lm::gather_rows(&self.params.get(W).data, d, &features);
            let outs = exe.run(&[
                HostTensor::f32(&[bsz, nnz, d], feat_emb),
                HostTensor::f32(&[bsz, nnz], values),
                // Fixed-shape view: scores the compiled base label set
                // even after extend_vocab grew the table.
                self.block_tensor_rows(CLS, n),
            ])?;
            let scores = outs[0].as_f32();
            p1 += batch_precision_at_k(scores, n, &labels, 1);
            p3 += batch_precision_at_k(scores, n, &labels, 3);
            p5 += batch_precision_at_k(scores, n, &labels, 5);
            batches += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(batches > 0, "no eval batches");
        let b = batches as f64;
        Ok((p1 / b, p3 / b, p5 / b))
    }
}
