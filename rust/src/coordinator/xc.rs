//! Extreme-classification training driver (AmazonCat-13K / Delicious-200K
//! / WikiLSHTC experiments, paper Table 3).
//!
//! Architecture (mirrors `python/compile/model.py::xc_*`): sparse features
//! → feature-embedding gather → weighted sum → L2-normalized h →
//! sampled softmax against the reduced multi-class target.
//!
//! On the default **native** backend the step runs through
//! [`crate::runtime::native`]: [`XcStep`] produces the raw weighted-sum
//! encoder output (the loss kernels own the L2 normalization and its
//! chain rule), [`FusedLoss`] does the one-pass sampled loss/grad sweep,
//! and [`XcStep::feat_grad`] scales the query grads back onto the
//! feature slots — all over reusable scratch (`scratch_growths` flat
//! after warmup). The legacy pjrt artifact path survives behind the
//! `pjrt` cargo feature.

use super::sampler_service::{build_sampler, SamplerService};
#[cfg(feature = "pjrt")]
use super::aggregate_rows;
use super::{step_cap, EvalPoint, RowAggregator, TrainReport};
use crate::config::{Config, SamplerKind};
use crate::data::extreme::{ExtremeDataset, ExtremeParams};
use crate::data::SparseBatch;
use crate::eval::batch_precision_at_k;
use crate::linalg::Matrix;
use crate::metrics::{Ewma, Metrics};
use crate::model::ParamStore;
use crate::optim::Optimizer;
use crate::rng::Rng;
use crate::runtime::native::{gather_rows_into, FullLoss, FusedLoss, XcStep};
#[cfg(feature = "pjrt")]
use crate::runtime::HostTensor;
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct XcShapes {
    pub n: usize,
    pub d: usize,
    pub v: usize,
    pub nnz: usize,
    pub batch: usize,
    pub m: usize,
    pub tau: f32,
}

/// Native-backend state: fused kernels + steady-state scratch (see
/// the `NativeLm` twin in `lm.rs` for the invariant).
struct NativeXc {
    xc: XcStep,
    fused: FusedLoss,
    full: FullLoss,
    feat_agg: RowAggregator,
    cls_agg: RowAggregator,
    tgt_emb: Vec<f32>,
    neg_emb: Vec<f32>,
    upd_buf: Vec<f32>,
    scores_buf: Vec<f32>,
    gather_growths: u64,
    reported_growths: u64,
}

impl NativeXc {
    fn new(workers: usize) -> Self {
        Self {
            xc: XcStep::new(workers),
            fused: FusedLoss::new(workers),
            full: FullLoss::new(workers),
            feat_agg: RowAggregator::new(),
            cls_agg: RowAggregator::new(),
            tgt_emb: Vec::new(),
            neg_emb: Vec::new(),
            upd_buf: Vec::new(),
            scores_buf: Vec::new(),
            gather_growths: 0,
            reported_growths: 0,
        }
    }

    fn growths(&self) -> u64 {
        self.xc.growths()
            + self.fused.growths()
            + self.full.growths()
            + self.gather_growths
    }
}

pub struct XcTrainer<'rt> {
    runtime: &'rt Runtime,
    /// Artifact-name prefix; only consulted by the pjrt entry points.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    prefix: String,
    cfg: Config,
    pub shapes: XcShapes,
    data: ExtremeDataset,
    params: ParamStore,
    optimizer: Optimizer,
    service: Option<SamplerService>,
    native: Option<NativeXc>,
    pub metrics: Metrics,
    rng: Rng,
    /// §4.2 normalization ablation (FULL only): skip the L2 norms
    /// (native) / use the `*_unnorm` artifact variants (pjrt).
    unnormalized: bool,
}

const W: usize = 0; // feature embeddings (v, d)
const CLS: usize = 1; // class embeddings (n, d)

impl<'rt> XcTrainer<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        prefix: &str,
        cfg: Config,
        unnormalized: bool,
    ) -> Result<Self> {
        super::validate_sampler_kind(cfg.sampler.kind)?;
        let shapes = if runtime.is_native() {
            XcShapes {
                n: cfg.model.num_classes,
                d: cfg.model.embed_dim,
                v: cfg.model.feature_dim,
                nnz: cfg.model.nnz,
                batch: cfg.train.batch_size,
                m: cfg.sampler.num_negatives,
                tau: cfg.model.tau,
            }
        } else {
            let meta = runtime
                .manifest()
                .get(&format!("{prefix}_train_sampled"))
                .ok_or_else(|| anyhow!("missing {prefix}_train_sampled"))?;
            let g = |k: &str| -> Result<usize> {
                meta.meta_usize(k)
                    .ok_or_else(|| anyhow!("manifest meta missing '{k}'"))
            };
            XcShapes {
                n: g("n")?,
                d: g("d")?,
                v: g("v")?,
                nnz: g("nnz")?,
                batch: g("batch")?,
                m: g("m")?,
                tau: meta.meta_f64("tau").ok_or_else(|| anyhow!("meta tau"))?
                    as f32,
            }
        };
        anyhow::ensure!(
            cfg.sampler.kind == SamplerKind::Full
                || cfg.sampler.num_negatives == shapes.m,
            "config m={} but step compiled for m={}",
            cfg.sampler.num_negatives,
            shapes.m
        );

        let data = ExtremeDataset::generate(&ExtremeParams {
            num_classes: shapes.n,
            feature_dim: shapes.v,
            latent_dim: cfg.data.latent_dim.max(2),
            nnz: shapes.nnz,
            labels_per_example: cfg.data.labels_per_example,
            train_examples: cfg.data.train_size,
            test_examples: cfg.data.valid_size,
            noise: cfg.data.noise,
            candidates: if shapes.n > 20_000 { 4096 } else { 0 },
            clusters: cfg.data.clusters,
            seed: cfg.data.seed,
        });

        let mut rng = Rng::seeded(cfg.train.seed);
        let mut params = ParamStore::new();
        assert_eq!(
            params.add_randn("w", &[shapes.v, shapes.d], 0.1, &mut rng),
            W
        );
        assert_eq!(
            params.add_randn("cls", &[shapes.n, shapes.d], 0.1, &mut rng),
            CLS
        );

        let service = if cfg.sampler.kind == SamplerKind::Full {
            None
        } else {
            let b = params.get(CLS);
            let normalized = Matrix::from_vec(b.rows(), b.cols(), b.data.clone())
                .l2_normalized_rows();
            let prior = data.class_prior();
            let sampler = build_sampler(&cfg, &normalized, Some(&prior), &mut rng)?;
            let svc_rng = Rng::seeded(cfg.sampler.seed);
            // serving.double_buffer (default on) overlaps tree refresh
            // with the step (see rust/src/serving); distribution-
            // identical to the synchronous path (stream-exact for exact
            // forks). Fork-less samplers degrade to synchronous updates
            // with a warning.
            Some(SamplerService::new_auto(
                sampler,
                shapes.m,
                svc_rng,
                cfg.serving.double_buffer,
            ))
        };

        let native = if runtime.is_native() {
            let workers = if cfg.train.workers == 0 {
                crate::exec::recommended_workers()
            } else {
                cfg.train.workers
            };
            Some(NativeXc::new(workers))
        } else {
            None
        };

        let optimizer = Optimizer::from_config(&cfg.train);
        Ok(Self {
            runtime,
            prefix: prefix.to_string(),
            cfg,
            shapes,
            data,
            params,
            optimizer,
            service,
            native,
            metrics: Metrics::new(),
            rng,
            unnormalized,
        })
    }

    #[cfg(feature = "pjrt")]
    fn artifact(&self, entry: &str) -> String {
        if self.unnormalized && matches!(entry, "train_full" | "scores") {
            format!("{}_{entry}_unnorm", self.prefix)
        } else {
            format!("{}_{entry}", self.prefix)
        }
    }

    #[cfg(feature = "pjrt")]
    fn train_entry(&self) -> String {
        match self.cfg.sampler.kind {
            SamplerKind::Full => self.artifact("train_full"),
            // The absolute-softmax loss ([12]'s pairing for the quadratic
            // kernel) is opt-in; see SamplerConfig::absolute.
            SamplerKind::Quadratic if self.cfg.sampler.absolute => {
                self.artifact("train_sampled_abs")
            }
            _ => self.artifact("train_sampled"),
        }
    }

    fn sampler_name(&self) -> &'static str {
        match &self.service {
            Some(s) => s.name(),
            None => "full",
        }
    }

    /// Grow the label universe mid-run (streaming extreme-classification
    /// deployments gain labels continuously): rows of `embeddings`
    /// become new classes with stable ids extending `0..n`. The CLS
    /// block grows in place (optimizer history preserved), the sampler
    /// tree grows in amortized `O(D log n)` per class, and the sampled
    /// train path keeps working unchanged (it gathers rows — it is
    /// n-independent). PREC@k evaluation keeps ranking the base label
    /// set.
    pub fn extend_vocab(&mut self, embeddings: &Matrix) -> Result<Vec<u32>> {
        super::extend_vocab_impl(
            self.service.as_mut(),
            &mut self.params,
            &mut self.optimizer,
            &mut self.metrics,
            CLS,
            self.shapes.d,
            embeddings,
        )
    }

    /// Retire live labels: permanent holes the sampler never draws again.
    /// See [`super::retire_classes_impl`] for the retired-target
    /// precondition on the data stream.
    pub fn retire_classes(&mut self, ids: &[u32]) -> Result<()> {
        super::retire_classes_impl(self.service.as_mut(), &mut self.metrics, ids)
    }

    /// First `rows` rows of a 2-D block — the compiled artifacts' fixed
    /// shape view of a table that may have grown past it.
    #[cfg(feature = "pjrt")]
    fn block_tensor_rows(&self, id: usize, rows: usize) -> HostTensor {
        super::block_rows_tensor(&self.params, id, rows)
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = Instant::now();
        let total_steps = step_cap()
            .map(|c| c.min(self.cfg.train.steps))
            .unwrap_or(self.cfg.train.steps);
        let bsz = self.shapes.batch;
        let ntrain = self.data.train.len();

        let mut ewma = Ewma::new(0.05);
        let mut history = Vec::new();
        for step in 1..=total_steps {
            // Batch assembly: random with-replacement example draw.
            let idx: Vec<usize> =
                (0..bsz).map(|_| self.rng.index(ntrain)).collect();
            let mut data_rng = self.rng.split();
            let batch = self.data.train_batch(&idx, &mut data_rng);
            let loss = self.step(&batch)?;
            let smooth = ewma.record(loss);
            self.metrics.observe("train_loss", loss);
            self.metrics.incr("steps", 1);

            if step % self.cfg.train.eval_every == 0 || step == total_steps {
                let (p1, p3, p5) = self.evaluate()?;
                history.push(EvalPoint {
                    step,
                    epoch: step as f64 * bsz as f64 / ntrain as f64,
                    train_loss: smooth,
                    eval_loss: smooth,
                    metric: p1,
                });
                self.metrics.observe("prec_at_3", p3);
                self.metrics.observe("prec_at_5", p5);
            }
        }

        if let Some(svc) = &self.service {
            svc.record_serving_metrics(&mut self.metrics);
        }

        let last = history.last().cloned().unwrap_or(EvalPoint {
            step: 0,
            epoch: 0.0,
            train_loss: f64::NAN,
            eval_loss: f64::NAN,
            metric: f64::NAN,
        });
        Ok(TrainReport {
            sampler: self.sampler_name().to_string(),
            history,
            final_metric: last.metric,
            final_eval_loss: last.eval_loss,
            steps_run: total_steps,
            wall_seconds: t0.elapsed().as_secs_f64(),
            metrics: self.metrics.to_json(),
        })
    }

    /// Final PREC@{1,3,5} (the Table-3 row for this sampler).
    pub fn final_precisions(&mut self) -> Result<(f64, f64, f64)> {
        self.evaluate()
    }

    fn step(&mut self, batch: &SparseBatch) -> Result<f64> {
        if self.runtime.is_native() {
            let loss = if self.cfg.sampler.kind == SamplerKind::Full {
                self.native_step_full(batch)?
            } else {
                self.native_step_sampled(batch)?
            };
            self.flush_growths();
            Ok(loss)
        } else if self.cfg.sampler.kind == SamplerKind::Full {
            self.pjrt_step_full(batch)
        } else {
            self.pjrt_step_sampled(batch)
        }
    }

    /// See `LmTrainer::flush_growths`: publishes scratch capacity growth
    /// as the `scratch_growths` counter (flat after warmup).
    fn flush_growths(&mut self) {
        if let Some(nat) = &mut self.native {
            let total = nat.growths();
            let delta = total - nat.reported_growths;
            if delta > 0 {
                self.metrics.incr("scratch_growths", delta);
                nat.reported_growths = total;
            }
        }
    }

    /// Fused native sampled step: raw weighted-sum encoder → batched
    /// negative draw → one-pass fused loss/grad → per-slot feature
    /// grads → sparse optimizer updates → batched tree propagation.
    fn native_step_sampled(&mut self, batch: &SparseBatch) -> Result<f64> {
        let XcShapes { d, nnz, batch: bsz, tau, .. } = self.shapes;
        let absolute = self.cfg.sampler.kind == SamplerKind::Quadratic
            && self.cfg.sampler.absolute;
        let nat = self.native.as_mut().expect("native step without state");
        let NativeXc {
            xc,
            fused,
            feat_agg,
            cls_agg,
            tgt_emb,
            neg_emb,
            upd_buf,
            gather_growths,
            ..
        } = nat;

        // 1. Encoder + negative draw. `xc.u` holds the *raw* weighted
        //    feature sums; the draw normalizes its own scratch copy and
        //    the fused loss owns the normalization chain rule.
        let t_sample = Instant::now();
        xc.forward(
            &self.params.get(W).data,
            d,
            &batch.features,
            &batch.values,
            bsz,
            nnz,
        );
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(&xc.u, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        // 2. Gather class rows + fused loss/grad + feature-slot grads.
        let t_exec = Instant::now();
        {
            let cls = self.params.get(CLS);
            if gather_rows_into(&cls.data, d, &batch.targets, tgt_emb) {
                *gather_growths += 1;
            }
            if gather_rows_into(&cls.data, d, &pack.ids, neg_emb) {
                *gather_growths += 1;
            }
        }
        let loss = fused.run(
            &mut xc.u,
            tgt_emb,
            neg_emb,
            &pack.adjust,
            &pack.mask,
            tau,
            absolute,
        ) as f64;
        xc.feat_grad(&fused.d_q, &batch.values, bsz, nnz, d);
        self.metrics.record_duration("execute", t_exec.elapsed());

        // 3. Sparse optimizer updates through the reusable aggregators.
        let t_opt = Instant::now();
        feat_agg.begin(d);
        for (k, &f) in batch.features.iter().enumerate() {
            feat_agg.add(f, &xc.d_feat[k * d..(k + 1) * d]);
        }
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(
                W,
                &mut param.data,
                d,
                feat_agg.rows(),
                feat_agg.grads(),
            );
        }
        cls_agg.begin(d);
        for (r, &t) in batch.targets.iter().enumerate() {
            cls_agg.add(t, &fused.d_tgt[r * d..(r + 1) * d]);
        }
        for (j, &id) in pack.ids.iter().enumerate() {
            cls_agg.add(id, &fused.d_neg[j * d..(j + 1) * d]);
        }
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_rows(
                CLS,
                &mut param.data,
                d,
                cls_agg.rows(),
                cls_agg.grads(),
            );
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // 4. Propagate the step's touched classes as one sharded batch.
        let t_tree = Instant::now();
        {
            let cls = self.params.get(CLS);
            let cap0 = upd_buf.capacity();
            upd_buf.clear();
            for &r in cls_agg.rows() {
                upd_buf.extend_from_slice(&cls.data[r * d..(r + 1) * d]);
            }
            if upd_buf.capacity() > cap0 {
                *gather_growths += 1;
            }
        }
        let upd =
            Matrix::from_vec(cls_agg.rows().len(), d, std::mem::take(upd_buf));
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(cls_agg.rows(), &upd);
        *upd_buf = upd.into_vec();
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        self.metrics.incr("tree_updates", cls_agg.rows().len() as u64);
        Ok(loss)
    }

    /// Native full-softmax step (FULL baseline / §4.2 ablation).
    fn native_step_full(&mut self, batch: &SparseBatch) -> Result<f64> {
        let XcShapes { n, d, nnz, batch: bsz, tau, .. } = self.shapes;
        let normalize = self.cfg.model.normalize && !self.unnormalized;
        let nat = self.native.as_mut().expect("native step without state");
        let NativeXc { xc, full, feat_agg, .. } = nat;

        let t_exec = Instant::now();
        xc.forward(
            &self.params.get(W).data,
            d,
            &batch.features,
            &batch.values,
            bsz,
            nnz,
        );
        full.prepare_classes(
            &self.params.get(CLS).data[..n * d],
            n,
            d,
            normalize,
        );
        let loss = full.forward(&mut xc.u, &batch.targets, tau) as f64;
        full.backward(&xc.u, &batch.targets, tau);
        xc.feat_grad(&full.d_q, &batch.values, bsz, nnz, d);
        self.metrics.record_duration("execute", t_exec.elapsed());

        let t_opt = Instant::now();
        feat_agg.begin(d);
        for (k, &f) in batch.features.iter().enumerate() {
            feat_agg.add(f, &xc.d_feat[k * d..(k + 1) * d]);
        }
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(
                W,
                &mut param.data,
                d,
                feat_agg.rows(),
                feat_agg.grads(),
            );
        }
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &full.d_cls);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());
        Ok(loss)
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_step_sampled(&mut self, _batch: &SparseBatch) -> Result<f64> {
        anyhow::bail!(
            "non-native runtime in a binary built without the `pjrt` \
             cargo feature"
        )
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_step_full(&mut self, _batch: &SparseBatch) -> Result<f64> {
        anyhow::bail!(
            "non-native runtime in a binary built without the `pjrt` \
             cargo feature"
        )
    }

    /// Per-example input embeddings h, computed Rust-side as the sampling
    /// query matrix (one L2-normalized row per example).
    #[cfg(feature = "pjrt")]
    fn queries_of_batch(&self, batch: &SparseBatch) -> Matrix {
        let d = self.shapes.d;
        let w = self.params.get(W);
        let mut q = Matrix::zeros(batch.batch, d);
        for i in 0..batch.batch {
            let (feats, vals) = batch.feature_row(i);
            let row = q.row_mut(i);
            crate::linalg::axpy_rows(&w.data, d, feats, vals, row);
            crate::linalg::l2_normalize(row);
        }
        q
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_step_sampled(&mut self, batch: &SparseBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, nnz, d, m) = (s.batch, s.nnz, s.d, s.m);

        let t_sample = Instant::now();
        let queries = self.queries_of_batch(batch);
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(&queries, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        let t_exec = Instant::now();
        let feat_emb = super::lm::gather_rows(
            &self.params.get(W).data,
            d,
            &batch.features,
        );
        let tgt_emb = super::lm::gather_rows(
            &self.params.get(CLS).data,
            d,
            &batch.targets,
        );
        let neg_emb =
            super::lm::gather_rows(&self.params.get(CLS).data, d, &pack.ids);
        let exe = self.runtime.get(&self.train_entry())?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, nnz, d], feat_emb),
            HostTensor::f32(&[bsz, nnz], batch.values.clone()),
            HostTensor::f32(&[bsz, d], tgt_emb),
            HostTensor::f32(&[m, d], neg_emb),
            HostTensor::f32(&[m], pack.adjust.clone()),
            HostTensor::f32(&[bsz, m], pack.mask.clone()),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        let t_opt = Instant::now();
        let (rows, grads) = aggregate_rows(&batch.features, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(W, &mut param.data, d, &rows, &grads);
        }
        let mut cls_ids: Vec<u32> = batch.targets.clone();
        cls_ids.extend_from_slice(&pack.ids);
        let mut cls_grads: Vec<f32> = outs[2].as_f32().to_vec();
        cls_grads.extend_from_slice(outs[3].as_f32());
        let (crow, cgrads) = aggregate_rows(&cls_ids, &cls_grads, d);
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_rows(CLS, &mut param.data, d, &crow, &cgrads);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // Propagate the step's touched classes as one sharded batch.
        let t_tree = Instant::now();
        let cls_block = self.params.get(CLS);
        let crow_u32: Vec<u32> = crow.iter().map(|&r| r as u32).collect();
        let upd = Matrix::from_vec(
            crow.len(),
            d,
            super::lm::gather_rows(&cls_block.data, d, &crow_u32),
        );
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(&crow, &upd);
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        Ok(loss)
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_step_full(&mut self, batch: &SparseBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, nnz, d) = (s.batch, s.nnz, s.d);
        let feat_emb = super::lm::gather_rows(
            &self.params.get(W).data,
            d,
            &batch.features,
        );
        let targets: Vec<i32> =
            batch.targets.iter().map(|&t| t as i32).collect();
        let exe = self.runtime.get(&self.artifact("train_full"))?;
        let t_exec = Instant::now();
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, nnz, d], feat_emb),
            HostTensor::f32(&[bsz, nnz], batch.values.clone()),
            self.block_tensor_rows(CLS, self.shapes.n),
            HostTensor::i32(&[bsz], targets),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        let (rows, grads) = aggregate_rows(&batch.features, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(W);
            self.optimizer.update_rows(W, &mut param.data, d, &rows, &grads);
        }
        {
            let grad = outs[2].as_f32().to_vec();
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &grad);
        }
        Ok(loss)
    }

    /// PREC@{1,3,5} on the test split.
    pub fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        if self.runtime.is_native() {
            self.native_evaluate()
        } else {
            self.pjrt_evaluate()
        }
    }

    /// Native eval: prepare the (normalized) class table once, then
    /// score each test chunk with the streaming kernel and rank.
    fn native_evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let XcShapes { n, d, nnz, batch: bsz, .. } = self.shapes;
        let normalize = self.cfg.model.normalize && !self.unnormalized;
        let t_eval = Instant::now();
        let nat = self.native.as_mut().expect("native eval without state");
        let NativeXc { xc, full, scores_buf, gather_growths, .. } = nat;
        // Fixed-shape view: rank the base label set even after
        // extend_vocab grew the table.
        full.prepare_classes(
            &self.params.get(CLS).data[..n * d],
            n,
            d,
            normalize,
        );
        if scores_buf.len() != bsz * n {
            scores_buf.resize(bsz * n, 0.0);
            *gather_growths += 1;
        }
        let mut p1 = 0.0;
        let mut p3 = 0.0;
        let mut p5 = 0.0;
        let mut batches = 0usize;
        let mut features = Vec::with_capacity(bsz * nnz);
        let mut values = Vec::with_capacity(bsz * nnz);
        let mut labels: Vec<Vec<u32>> = Vec::with_capacity(bsz);
        let eval_examples = (self.cfg.train.eval_batches * bsz)
            .min(self.data.test.len() / bsz * bsz);
        for chunk_start in (0..eval_examples).step_by(bsz) {
            if chunk_start + bsz > eval_examples {
                break;
            }
            features.clear();
            values.clear();
            labels.clear();
            for i in chunk_start..chunk_start + bsz {
                let ex = &self.data.test[i];
                features.extend_from_slice(&ex.features);
                values.extend_from_slice(&ex.values);
                labels.push(ex.labels.clone());
            }
            xc.forward(
                &self.params.get(W).data,
                d,
                &features,
                &values,
                bsz,
                nnz,
            );
            full.scores_into(&mut xc.u, scores_buf);
            p1 += batch_precision_at_k(scores_buf, n, &labels, 1);
            p3 += batch_precision_at_k(scores_buf, n, &labels, 3);
            p5 += batch_precision_at_k(scores_buf, n, &labels, 5);
            batches += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(batches > 0, "no eval batches");
        let b = batches as f64;
        Ok((p1 / b, p3 / b, p5 / b))
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_evaluate(&mut self) -> Result<(f64, f64, f64)> {
        anyhow::bail!(
            "non-native runtime in a binary built without the `pjrt` \
             cargo feature"
        )
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let s = &self.shapes;
        let (bsz, nnz, d, n) = (s.batch, s.nnz, s.d, s.n);
        let exe = self.runtime.get(&self.artifact("scores"))?;
        let t_eval = Instant::now();
        let mut p1 = 0.0;
        let mut p3 = 0.0;
        let mut p5 = 0.0;
        let mut batches = 0usize;
        let eval_examples = (self.cfg.train.eval_batches * bsz)
            .min(self.data.test.len() / bsz * bsz);
        for chunk in (0..eval_examples).collect::<Vec<_>>().chunks(bsz) {
            if chunk.len() < bsz {
                break;
            }
            let mut features = Vec::with_capacity(bsz * nnz);
            let mut values = Vec::with_capacity(bsz * nnz);
            let mut labels: Vec<Vec<u32>> = Vec::with_capacity(bsz);
            for &i in chunk {
                let ex = &self.data.test[i];
                features.extend_from_slice(&ex.features);
                values.extend_from_slice(&ex.values);
                labels.push(ex.labels.clone());
            }
            let feat_emb =
                super::lm::gather_rows(&self.params.get(W).data, d, &features);
            let outs = exe.run(&[
                HostTensor::f32(&[bsz, nnz, d], feat_emb),
                HostTensor::f32(&[bsz, nnz], values),
                // Fixed-shape view: scores the compiled base label set
                // even after extend_vocab grew the table.
                self.block_tensor_rows(CLS, n),
            ])?;
            let scores = outs[0].as_f32();
            p1 += batch_precision_at_k(scores, n, &labels, 1);
            p3 += batch_precision_at_k(scores, n, &labels, 3);
            p5 += batch_precision_at_k(scores, n, &labels, 5);
            batches += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(batches > 0, "no eval batches");
        let b = batches as f64;
        Ok((p1 / b, p3 / b, p5 / b))
    }
}
