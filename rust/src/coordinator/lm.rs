//! Language-model training driver (PennTreeBank / Bnews experiments,
//! paper Figures 1–4).
//!
//! Architecture (mirrors `python/compile/model.py::lm_*`):
//! context tokens → input-embedding gather (Rust) → LSTM → projection →
//! L2-normalized h → sampled-softmax loss against target + shared
//! negatives. The AOT executables do the differentiable math; Rust does
//! gathers/scatters, sampling, optimization and tree propagation.

use super::sampler_service::{build_sampler, SamplerService};
use super::{aggregate_rows, step_cap, EvalPoint, TrainReport};
use crate::config::{Config, SamplerKind};
use crate::data::synthlm::{Split, SynthCorpus, SynthLmParams};
use crate::data::LmBatch;
use crate::eval::perplexity;
use crate::linalg::{l2_normalize, Matrix};
use crate::metrics::{Ewma, Metrics};
use crate::model::ParamStore;
use crate::optim::Optimizer;
use crate::rng::Rng;
use crate::runtime::{HostTensor, Runtime};
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Shapes discovered from the manifest.
#[derive(Clone, Debug)]
pub struct LmShapes {
    pub n: usize,
    pub d: usize,
    pub hidden: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub m: usize,
    pub tau: f32,
}

pub struct LmTrainer<'rt> {
    runtime: &'rt Runtime,
    prefix: String,
    cfg: Config,
    pub shapes: LmShapes,
    corpus: Arc<SynthCorpus>,
    params: ParamStore,
    optimizer: Optimizer,
    service: Option<SamplerService>,
    pub metrics: Metrics,
    #[allow(dead_code)] rng: Rng, // reserved for trainer-side stochastic features
    stale_sampling: bool,
    /// Use the `*_unnorm` artifact variants (§4.2 ablation; FULL only).
    unnormalized: bool,
    /// Query embedding carried across steps in stale-sampling mode.
    prev_query: Vec<f32>,
}

// Parameter block ids (order matters for nothing but readability).
const EMB: usize = 0;
const WX: usize = 1;
const WH: usize = 2;
const BIAS: usize = 3;
const PROJ: usize = 4;
const CLS: usize = 5;

impl<'rt> LmTrainer<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        prefix: &str,
        cfg: Config,
        stale_sampling: bool,
        unnormalized: bool,
    ) -> Result<Self> {
        super::validate_sampler_kind(cfg.sampler.kind)?;
        let meta = runtime
            .manifest()
            .get(&format!("{prefix}_train_sampled"))
            .ok_or_else(|| anyhow!("missing {prefix}_train_sampled"))?;
        let g = |k: &str| -> Result<usize> {
            meta.meta_usize(k)
                .ok_or_else(|| anyhow!("manifest meta missing '{k}'"))
        };
        let shapes = LmShapes {
            n: g("n")?,
            d: g("d")?,
            hidden: g("hidden")?,
            seq_len: g("seq_len")?,
            batch: g("batch")?,
            m: g("m")?,
            tau: meta.meta_f64("tau").ok_or_else(|| anyhow!("meta tau"))?
                as f32,
        };

        // --- data -----------------------------------------------------
        let corpus = Arc::new(SynthCorpus::generate(&SynthLmParams {
            vocab_size: shapes.n,
            zipf_s: cfg.data.zipf_s,
            rank: cfg.data.markov_rank,
            markov_weight: cfg.data.markov_weight,
            train_tokens: cfg.data.train_size,
            valid_tokens: cfg.data.valid_size,
            seed: cfg.data.seed,
        }));

        // --- parameters -------------------------------------------------
        let mut rng = Rng::seeded(cfg.train.seed);
        let mut params = ParamStore::new();
        let (n, d, h) = (shapes.n, shapes.d, shapes.hidden);
        let id = params.add_randn("emb", &[n, d], 0.1, &mut rng);
        assert_eq!(id, EMB);
        let scale = 1.0 / (h as f32).sqrt();
        assert_eq!(params.add_randn("wx", &[d, 4 * h], scale, &mut rng), WX);
        assert_eq!(params.add_randn("wh", &[h, 4 * h], scale, &mut rng), WH);
        assert_eq!(params.add_zeros("b", &[4 * h]), BIAS);
        // Forget-gate bias init = 1 (gate order: i, f, g, o).
        {
            let b = params.get_mut(BIAS);
            for v in &mut b.data[h..2 * h] {
                *v = 1.0;
            }
        }
        assert_eq!(params.add_randn("proj", &[h, d], scale, &mut rng), PROJ);
        assert_eq!(params.add_randn("cls", &[n, d], 0.1, &mut rng), CLS);

        // --- sampling service -------------------------------------------
        let service = if cfg.sampler.kind == SamplerKind::Full {
            None
        } else {
            let normalized = normalized_classes(&params, CLS);
            let unigram = corpus.unigram_prior();
            let sampler =
                build_sampler(&cfg, &normalized, Some(&unigram), &mut rng)?;
            // The artifact is compiled for exactly m negatives.
            anyhow::ensure!(
                cfg.sampler.num_negatives == shapes.m,
                "config m={} but artifact compiled for m={}",
                cfg.sampler.num_negatives,
                shapes.m
            );
            let svc_rng = Rng::seeded(cfg.sampler.seed);
            // serving.double_buffer (default on) stages each step's
            // update_classes into a shadow sampler on a writer thread so
            // the tree refresh overlaps the step; the swap lands before
            // the next draw (see rust/src/serving). Distribution-
            // identical to the synchronous path (and stream-identical
            // when the sampler's fork is exact, e.g. sharded trees).
            // Samplers without a serving fork (the quadratic bucket
            // fallback) degrade to synchronous updates with a warning.
            Some(SamplerService::new_auto(
                sampler,
                shapes.m,
                svc_rng,
                cfg.serving.double_buffer,
            ))
        };

        let optimizer = Optimizer::from_config(&cfg.train);
        Ok(Self {
            runtime,
            prefix: prefix.to_string(),
            cfg,
            shapes,
            corpus,
            params,
            optimizer,
            service,
            metrics: Metrics::new(),
            rng,
            stale_sampling,
            unnormalized,
            prev_query: Vec::new(),
        })
    }

    fn artifact(&self, entry: &str) -> String {
        if self.unnormalized && matches!(entry, "train_full" | "eval") {
            format!("{}_{entry}_unnorm", self.prefix)
        } else {
            format!("{}_{entry}", self.prefix)
        }
    }

    /// Grow the label universe mid-run: each row of `embeddings` (any
    /// scale; the sampling service normalizes its copy) becomes a new
    /// class, returned as stable ids extending `0..n`. The CLS parameter
    /// block grows in place (optimizer state padded, history preserved)
    /// and the sampler's tree grows in amortized `O(D log n)` per class —
    /// under `serving.double_buffer` as an epoch-versioned snapshot swap
    /// that lands before the next draw. Training keeps working because
    /// the sampled-loss artifacts are *n-independent* (they consume
    /// gathered target/negative rows, never the full table); the
    /// full-softmax eval keeps scoring the compiled base vocabulary,
    /// which is exactly the corpus's label space.
    pub fn extend_vocab(&mut self, embeddings: &Matrix) -> Result<Vec<u32>> {
        super::extend_vocab_impl(
            self.service.as_mut(),
            &mut self.params,
            &mut self.optimizer,
            &mut self.metrics,
            CLS,
            self.shapes.d,
            embeddings,
        )
    }

    /// Retire live classes: permanent holes the sampler never draws
    /// again. The CLS rows stay allocated (ids are stable), they just
    /// stop receiving sampling mass. See
    /// [`super::retire_classes_impl`] for the retired-target
    /// precondition on the data stream.
    pub fn retire_classes(&mut self, ids: &[u32]) -> Result<()> {
        super::retire_classes_impl(self.service.as_mut(), &mut self.metrics, ids)
    }

    /// Which training artifact this sampler uses: the Quadratic baseline
    /// optimizes the absolute-softmax loss (paper §4.1).
    fn train_entry(&self) -> String {
        match self.cfg.sampler.kind {
            SamplerKind::Full => self.artifact("train_full"),
            // The absolute-softmax loss ([12]'s pairing for the quadratic
            // kernel) is opt-in; see SamplerConfig::absolute.
            SamplerKind::Quadratic if self.cfg.sampler.absolute => {
                self.artifact("train_sampled_abs")
            }
            _ => self.artifact("train_sampled"),
        }
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = Instant::now();
        let shapes = self.shapes.clone();
        let total_steps = step_cap()
            .map(|c| c.min(self.cfg.train.steps))
            .unwrap_or(self.cfg.train.steps);
        let bsz = shapes.batch;

        // Bounded prefetch of training batches (producer thread).
        let corpus = Arc::clone(&self.corpus);
        let (seq_len, depth) = (shapes.seq_len, self.cfg.train.pipeline_depth);
        let base_seed = self.cfg.data.seed;
        let prefetcher = crate::exec::Prefetcher::spawn(
            depth,
            Some(total_steps),
            move |i| {
                // Re-derive the batch for global step i: epoch-major order.
                let windows = corpus.train.len() - seq_len;
                let steps_per_epoch = (windows / bsz).max(1);
                let epoch = i / steps_per_epoch;
                let within = i % steps_per_epoch;
                corpus
                    .batches(
                        Split::Train,
                        seq_len,
                        bsz,
                        base_seed ^ (epoch as u64).wrapping_mul(0x9E3779B9),
                    )
                    .nth(within)
                    .expect("batch index out of range")
            },
        );

        let mut ewma = Ewma::new(0.05);
        let mut history = Vec::new();
        let mut step = 0usize;
        while let Some(batch) = prefetcher.next() {
            let loss = self.step(&batch)?;
            let smooth = ewma.record(loss);
            self.metrics.observe("train_loss", loss);
            self.metrics.incr("steps", 1);
            step += 1;

            if step % self.cfg.train.eval_every == 0 || step == total_steps {
                let (eval_loss, ppl) = self.evaluate()?;
                let windows = self.corpus.train.len() - shapes.seq_len;
                history.push(EvalPoint {
                    step,
                    epoch: step as f64 * bsz as f64 / windows as f64,
                    train_loss: smooth,
                    eval_loss,
                    metric: ppl,
                });
            }
            if step >= total_steps {
                break;
            }
        }
        let stats = &prefetcher.stats();
        self.metrics.incr(
            "pipeline_producer_stalls",
            stats.producer_stalls.load(std::sync::atomic::Ordering::Relaxed),
        );
        self.metrics.incr(
            "pipeline_consumer_stalls",
            stats.consumer_stalls.load(std::sync::atomic::Ordering::Relaxed),
        );
        if let Some(svc) = &self.service {
            svc.record_serving_metrics(&mut self.metrics);
        }

        if let Some(dir) = self.cfg.train.checkpoint_dir.clone() {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("mkdir {dir}"))?;
            let path = std::path::Path::new(&dir)
                .join(format!("{}_{}.ckpt", self.prefix, self.sampler_name()));
            self.params.save(&path)?;
        }

        let last = history.last().cloned().unwrap_or(EvalPoint {
            step,
            epoch: 0.0,
            train_loss: f64::NAN,
            eval_loss: f64::NAN,
            metric: f64::NAN,
        });
        Ok(TrainReport {
            sampler: self.sampler_name().to_string(),
            history,
            final_metric: last.metric,
            final_eval_loss: last.eval_loss,
            steps_run: step,
            wall_seconds: t0.elapsed().as_secs_f64(),
            metrics: self.metrics.to_json(),
        })
    }

    fn sampler_name(&self) -> &'static str {
        match &self.service {
            Some(s) => s.name(),
            None => "full",
        }
    }

    /// One optimizer step; returns the training loss.
    fn step(&mut self, batch: &LmBatch) -> Result<f64> {
        if self.cfg.sampler.kind == SamplerKind::Full {
            self.step_full(batch)
        } else {
            self.step_sampled(batch)
        }
    }

    fn step_sampled(&mut self, batch: &LmBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, seq_len, d, m) = (s.batch, s.seq_len, s.d, s.m);

        // 1. Gather context embeddings.
        let t_gather = Instant::now();
        let ctx_emb = gather_rows(self.params.get(EMB).data_view(), d, &batch.contexts);
        self.metrics.record_duration("gather", t_gather.elapsed());

        // 2. Per-example query rows for sampling: encoder pass (or, in
        //    stale mode, a single-row pool — replicating the stale query
        //    would only multiply φ work on identical rows).
        let t_sample = Instant::now();
        let queries: Matrix = if self.stale_sampling && !self.prev_query.is_empty()
        {
            Matrix::from_vec(1, d, self.prev_query.clone())
        } else {
            let enc = self.runtime.get(&self.artifact("encode"))?;
            let outs = enc.run(&[
                HostTensor::f32(&[bsz, seq_len, d], ctx_emb.clone()),
                self.block_tensor(WX),
                self.block_tensor(WH),
                self.block_tensor(BIAS),
                self.block_tensor(PROJ),
            ])?;
            Matrix::from_vec(bsz, d, outs[0].as_f32().to_vec())
        };

        // 3. One batched draw serves the whole step: shared negatives
        //    drawn from the batch's per-example queries (round-robin slot
        //    ownership, exact per-slot probabilities), masks batch-wide.
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(&queries, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        // 4. Gather class rows and execute the fused train step.
        let t_exec = Instant::now();
        let tgt_emb = gather_rows(self.params.get(CLS).data_view(), d, &batch.targets);
        let neg_emb = gather_rows(self.params.get(CLS).data_view(), d, &pack.ids);
        let exe = self.runtime.get(&self.train_entry())?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
            self.block_tensor(WX),
            self.block_tensor(WH),
            self.block_tensor(BIAS),
            self.block_tensor(PROJ),
            HostTensor::f32(&[bsz, d], tgt_emb),
            HostTensor::f32(&[m, d], neg_emb),
            HostTensor::f32(&[m], pack.adjust.clone()),
            HostTensor::f32(&[bsz, m], pack.mask.clone()),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        // 5. Optimizer updates.
        let t_opt = Instant::now();
        // Dense blocks.
        for (block, out_idx) in [(WX, 2), (WH, 3), (BIAS, 4), (PROJ, 5)] {
            let grad = outs[out_idx].as_f32().to_vec();
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, &grad);
        }
        // Sparse: input embeddings (contexts).
        let (rows, grads) = aggregate_rows(&batch.contexts, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(EMB, &mut param.data, d, &rows, &grads);
        }
        // Sparse: class embeddings (targets + negatives).
        let mut cls_ids: Vec<u32> = batch.targets.clone();
        cls_ids.extend_from_slice(&pack.ids);
        let mut cls_grads: Vec<f32> = outs[6].as_f32().to_vec();
        cls_grads.extend_from_slice(outs[7].as_f32());
        let (crow, cgrads) = aggregate_rows(&cls_ids, &cls_grads, d);
        {
            let param = self.params.get_mut(CLS);
            self.optimizer
                .update_rows(CLS, &mut param.data, d, &crow, &cgrads);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // 6. Propagate updated class embeddings to the sampling tree as
        //    one batch: φ recomputation collapses into two gemms and
        //    sharded trees absorb disjoint shards in parallel.
        let t_tree = Instant::now();
        let cls_block = self.params.get(CLS);
        let crow_u32: Vec<u32> = crow.iter().map(|&r| r as u32).collect();
        let upd = Matrix::from_vec(
            crow.len(),
            d,
            gather_rows(&cls_block.data, d, &crow_u32),
        );
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(&crow, &upd);
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        self.metrics.incr("tree_updates", crow.len() as u64);

        if self.stale_sampling {
            self.prev_query = mean_query_from_rows(self.params.get(CLS), &batch.targets, d);
        }
        Ok(loss)
    }

    fn step_full(&mut self, batch: &LmBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, seq_len, d, n) = (s.batch, s.seq_len, s.d, s.n);
        let ctx_emb = gather_rows(self.params.get(EMB).data_view(), d, &batch.contexts);
        let targets: Vec<i32> =
            batch.targets.iter().map(|&t| t as i32).collect();
        let t_exec = Instant::now();
        let exe = self.runtime.get(&self.artifact("train_full"))?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
            self.block_tensor(WX),
            self.block_tensor(WH),
            self.block_tensor(BIAS),
            self.block_tensor(PROJ),
            self.block_tensor_rows(CLS, n),
            HostTensor::i32(&[bsz], targets),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        for (block, out_idx) in [(WX, 2), (WH, 3), (BIAS, 4), (PROJ, 5)] {
            let grad = outs[out_idx].as_f32().to_vec();
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, &grad);
        }
        let (rows, grads) = aggregate_rows(&batch.contexts, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(EMB, &mut param.data, d, &rows, &grads);
        }
        {
            let grad = outs[6].as_f32().to_vec();
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &grad);
        }
        Ok(loss)
    }

    /// Full-softmax validation loss + perplexity over `eval_batches`.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let s = &self.shapes;
        let (bsz, seq_len, d) = (s.batch, s.seq_len, s.d);
        let exe = self.runtime.get(&self.artifact("eval"))?;
        let mut total = 0.0;
        let mut count = 0usize;
        let t_eval = Instant::now();
        for batch in self
            .corpus
            .batches(Split::Valid, seq_len, bsz, 0)
            .take(self.cfg.train.eval_batches)
        {
            let ctx_emb =
                gather_rows(self.params.get(EMB).data_view(), d, &batch.contexts);
            let targets: Vec<i32> =
                batch.targets.iter().map(|&t| t as i32).collect();
            let outs = exe.run(&[
                HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
                self.block_tensor(WX),
                self.block_tensor(WH),
                self.block_tensor(BIAS),
                self.block_tensor(PROJ),
                // Fixed-shape view: the compiled eval scores the base
                // vocabulary even after extend_vocab grew the table.
                self.block_tensor_rows(CLS, self.shapes.n),
                HostTensor::i32(&[bsz], targets),
            ])?;
            total += outs[0].scalar() as f64;
            count += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(count > 0, "no validation batches");
        let mean = total / count as f64;
        Ok((mean, perplexity(mean)))
    }

    fn block_tensor(&self, id: usize) -> HostTensor {
        let b = self.params.get(id);
        HostTensor::f32(&b.shape, b.data.clone())
    }

    /// First `rows` rows of a 2-D block — the compiled artifacts' fixed
    /// shape view of a table that may have grown past it via
    /// [`LmTrainer::extend_vocab`].
    fn block_tensor_rows(&self, id: usize, rows: usize) -> HostTensor {
        super::block_rows_tensor(&self.params, id, rows)
    }
}

/// Normalized copy of the class-embedding block as a Matrix.
fn normalized_classes(params: &ParamStore, id: usize) -> Matrix {
    let b = params.get(id);
    Matrix::from_vec(b.rows(), b.cols(), b.data.clone()).l2_normalized_rows()
}

/// Gather `ids` rows from a flat `rows × dim` table.
pub(crate) fn gather_rows(table: &[f32], dim: usize, ids: &[u32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(ids.len() * dim);
    for &id in ids {
        let s = id as usize * dim;
        out.extend_from_slice(&table[s..s + dim]);
    }
    out
}

/// Normalized mean of the batch's h rows — the pre-batch-pipeline shared
/// sampling query, kept for diagnostics and A/B comparisons against
/// per-example batch queries.
#[allow(dead_code)]
pub(crate) fn mean_query(h: &[f32], bsz: usize, d: usize) -> Vec<f32> {
    let mut q = vec![0.0f32; d];
    for b in 0..bsz {
        for (qi, &hi) in q.iter_mut().zip(&h[b * d..(b + 1) * d]) {
            *qi += hi;
        }
    }
    l2_normalize(&mut q);
    q
}

fn mean_query_from_rows(
    block: &crate::model::Block,
    ids: &[u32],
    d: usize,
) -> Vec<f32> {
    let mut q = vec![0.0f32; d];
    for &id in ids {
        for (qi, &v) in q.iter_mut().zip(block.row(id as usize)) {
            *qi += v;
        }
    }
    l2_normalize(&mut q);
    q
}

// Helper trait to view a Block's data as a slice without borrowing issues.
trait DataView {
    fn data_view(&self) -> &[f32];
}

impl DataView for crate::model::Block {
    fn data_view(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_rows_layout() {
        let table = vec![0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0];
        let out = gather_rows(&table, 2, &[2, 0]);
        assert_eq!(out, vec![20.0, 21.0, 0.0, 1.0]);
    }

    #[test]
    fn mean_query_is_normalized() {
        let h = vec![1.0f32, 0.0, 0.0, 1.0]; // two 2-d rows
        let q = mean_query(&h, 2, 2);
        let norm: f32 = q.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        assert!((q[0] - q[1]).abs() < 1e-6);
    }
}
