//! Language-model training driver (PennTreeBank / Bnews experiments,
//! paper Figures 1–4).
//!
//! Architecture (mirrors `python/compile/model.py::lm_*`):
//! context tokens → input-embedding gather → LSTM → projection →
//! L2-normalized h → sampled-softmax loss against target + shared
//! negatives.
//!
//! On the default **native** backend the whole step runs in-process
//! through the fused kernels in [`crate::runtime::native`]: one blocked
//! LSTM forward, one fused loss+gradient sweep (no `bsz×m`
//! intermediates), one BPTT backward — all over reusable per-trainer
//! scratch, so a steady-state step allocates nothing (tracked by the
//! `scratch_growths` metric). The legacy **pjrt** backend (behind the
//! `pjrt` cargo feature) executes the AOT HLO artifacts instead; Rust
//! then only does gathers/scatters, sampling, optimization and tree
//! propagation.

use super::sampler_service::{build_sampler, SamplerService};
#[cfg(feature = "pjrt")]
use super::aggregate_rows;
use super::{step_cap, EvalPoint, RowAggregator, TrainReport};
use crate::config::{Config, SamplerKind};
use crate::data::synthlm::{Split, SynthCorpus, SynthLmParams};
use crate::data::LmBatch;
use crate::eval::perplexity;
use crate::linalg::{l2_normalize, Matrix};
use crate::metrics::{Ewma, Metrics};
use crate::model::ParamStore;
use crate::optim::Optimizer;
use crate::rng::Rng;
use crate::runtime::native::{gather_rows_into, FullLoss, FusedLoss, LmStep};
#[cfg(feature = "pjrt")]
use crate::runtime::HostTensor;
use crate::runtime::Runtime;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Model shapes: from the [`Config`] on the native backend, from the
/// artifact manifest on pjrt (so the Rust side can never drift from
/// what the Python AOT pipeline compiled).
#[derive(Clone, Debug)]
pub struct LmShapes {
    pub n: usize,
    pub d: usize,
    pub hidden: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub m: usize,
    pub tau: f32,
}

/// Per-trainer native-backend state: the fused kernels plus every
/// steady-state scratch buffer. After the first step has sized them,
/// a training step performs no data-plane allocations; the growth
/// counters prove it (surfaced as the `scratch_growths` metric, which
/// must stay flat after warmup).
struct NativeLm {
    lm: LmStep,
    fused: FusedLoss,
    full: FullLoss,
    emb_agg: RowAggregator,
    cls_agg: RowAggregator,
    tgt_emb: Vec<f32>,
    neg_emb: Vec<f32>,
    upd_buf: Vec<f32>,
    stale_q: Matrix,
    gather_growths: u64,
    reported_growths: u64,
}

impl NativeLm {
    fn new(workers: usize) -> Self {
        Self {
            lm: LmStep::new(workers),
            fused: FusedLoss::new(workers),
            full: FullLoss::new(workers),
            emb_agg: RowAggregator::new(),
            cls_agg: RowAggregator::new(),
            tgt_emb: Vec::new(),
            neg_emb: Vec::new(),
            upd_buf: Vec::new(),
            stale_q: Matrix::zeros(1, 1),
            gather_growths: 0,
            reported_growths: 0,
        }
    }

    fn growths(&self) -> u64 {
        self.lm.growths()
            + self.fused.growths()
            + self.full.growths()
            + self.gather_growths
    }
}

pub struct LmTrainer<'rt> {
    runtime: &'rt Runtime,
    prefix: String,
    cfg: Config,
    pub shapes: LmShapes,
    corpus: Arc<SynthCorpus>,
    params: ParamStore,
    optimizer: Optimizer,
    service: Option<SamplerService>,
    native: Option<NativeLm>,
    pub metrics: Metrics,
    stale_sampling: bool,
    /// §4.2 normalization ablation (FULL only): skip the L2 normalization
    /// of h and the class table (native) / use the `*_unnorm` artifact
    /// variants (pjrt).
    unnormalized: bool,
    /// Query embedding carried across steps in stale-sampling mode.
    prev_query: Vec<f32>,
}

// Parameter block ids (order matters for nothing but readability).
const EMB: usize = 0;
const WX: usize = 1;
const WH: usize = 2;
const BIAS: usize = 3;
const PROJ: usize = 4;
const CLS: usize = 5;

impl<'rt> LmTrainer<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        prefix: &str,
        cfg: Config,
        stale_sampling: bool,
        unnormalized: bool,
    ) -> Result<Self> {
        super::validate_sampler_kind(cfg.sampler.kind)?;
        let shapes = if runtime.is_native() {
            LmShapes {
                n: cfg.model.num_classes,
                d: cfg.model.embed_dim,
                hidden: cfg.model.hidden_dim,
                seq_len: cfg.model.seq_len,
                batch: cfg.train.batch_size,
                m: cfg.sampler.num_negatives,
                tau: cfg.model.tau,
            }
        } else {
            let meta = runtime
                .manifest()
                .get(&format!("{prefix}_train_sampled"))
                .ok_or_else(|| anyhow!("missing {prefix}_train_sampled"))?;
            let g = |k: &str| -> Result<usize> {
                meta.meta_usize(k)
                    .ok_or_else(|| anyhow!("manifest meta missing '{k}'"))
            };
            LmShapes {
                n: g("n")?,
                d: g("d")?,
                hidden: g("hidden")?,
                seq_len: g("seq_len")?,
                batch: g("batch")?,
                m: g("m")?,
                tau: meta.meta_f64("tau").ok_or_else(|| anyhow!("meta tau"))?
                    as f32,
            }
        };

        // --- data -----------------------------------------------------
        let corpus = Arc::new(SynthCorpus::generate(&SynthLmParams {
            vocab_size: shapes.n,
            zipf_s: cfg.data.zipf_s,
            rank: cfg.data.markov_rank,
            markov_weight: cfg.data.markov_weight,
            train_tokens: cfg.data.train_size,
            valid_tokens: cfg.data.valid_size,
            seed: cfg.data.seed,
        }));

        // --- parameters -------------------------------------------------
        let mut rng = Rng::seeded(cfg.train.seed);
        let mut params = ParamStore::new();
        let (n, d, h) = (shapes.n, shapes.d, shapes.hidden);
        let id = params.add_randn("emb", &[n, d], 0.1, &mut rng);
        assert_eq!(id, EMB);
        let scale = 1.0 / (h as f32).sqrt();
        assert_eq!(params.add_randn("wx", &[d, 4 * h], scale, &mut rng), WX);
        assert_eq!(params.add_randn("wh", &[h, 4 * h], scale, &mut rng), WH);
        assert_eq!(params.add_zeros("b", &[4 * h]), BIAS);
        // Forget-gate bias init = 1 (gate order: i, f, g, o).
        {
            let b = params.get_mut(BIAS);
            for v in &mut b.data[h..2 * h] {
                *v = 1.0;
            }
        }
        assert_eq!(params.add_randn("proj", &[h, d], scale, &mut rng), PROJ);
        assert_eq!(params.add_randn("cls", &[n, d], 0.1, &mut rng), CLS);

        // --- sampling service -------------------------------------------
        let service = if cfg.sampler.kind == SamplerKind::Full {
            None
        } else {
            let normalized = normalized_classes(&params, CLS);
            let unigram = corpus.unigram_prior();
            let sampler =
                build_sampler(&cfg, &normalized, Some(&unigram), &mut rng)?;
            // The step kernel (native) / artifact (pjrt) is shaped for
            // exactly m negatives.
            anyhow::ensure!(
                cfg.sampler.num_negatives == shapes.m,
                "config m={} but step compiled for m={}",
                cfg.sampler.num_negatives,
                shapes.m
            );
            let svc_rng = Rng::seeded(cfg.sampler.seed);
            // serving.double_buffer (default on) stages each step's
            // update_classes into a shadow sampler on a writer thread so
            // the tree refresh overlaps the step; the swap lands before
            // the next draw (see rust/src/serving). Distribution-
            // identical to the synchronous path (and stream-identical
            // when the sampler's fork is exact, e.g. sharded trees).
            // Samplers without a serving fork (the quadratic bucket
            // fallback) degrade to synchronous updates with a warning.
            Some(SamplerService::new_auto(
                sampler,
                shapes.m,
                svc_rng,
                cfg.serving.double_buffer,
            ))
        };

        let native = if runtime.is_native() {
            let workers = if cfg.train.workers == 0 {
                crate::exec::recommended_workers()
            } else {
                cfg.train.workers
            };
            Some(NativeLm::new(workers))
        } else {
            None
        };

        let optimizer = Optimizer::from_config(&cfg.train);
        Ok(Self {
            runtime,
            prefix: prefix.to_string(),
            cfg,
            shapes,
            corpus,
            params,
            optimizer,
            service,
            native,
            metrics: Metrics::new(),
            stale_sampling,
            unnormalized,
            prev_query: Vec::new(),
        })
    }

    #[cfg(feature = "pjrt")]
    fn artifact(&self, entry: &str) -> String {
        if self.unnormalized && matches!(entry, "train_full" | "eval") {
            format!("{}_{entry}_unnorm", self.prefix)
        } else {
            format!("{}_{entry}", self.prefix)
        }
    }

    /// Grow the label universe mid-run: each row of `embeddings` (any
    /// scale; the sampling service normalizes its copy) becomes a new
    /// class, returned as stable ids extending `0..n`. The CLS parameter
    /// block grows in place (optimizer state padded, history preserved)
    /// and the sampler's tree grows in amortized `O(D log n)` per class —
    /// under `serving.double_buffer` as an epoch-versioned snapshot swap
    /// that lands before the next draw. Training keeps working because
    /// the sampled-loss step is *n-independent* (it consumes gathered
    /// target/negative rows, never the full table); the full-softmax
    /// eval keeps scoring the base vocabulary, which is exactly the
    /// corpus's label space.
    pub fn extend_vocab(&mut self, embeddings: &Matrix) -> Result<Vec<u32>> {
        super::extend_vocab_impl(
            self.service.as_mut(),
            &mut self.params,
            &mut self.optimizer,
            &mut self.metrics,
            CLS,
            self.shapes.d,
            embeddings,
        )
    }

    /// Retire live classes: permanent holes the sampler never draws
    /// again. The CLS rows stay allocated (ids are stable), they just
    /// stop receiving sampling mass. See
    /// [`super::retire_classes_impl`] for the retired-target
    /// precondition on the data stream.
    pub fn retire_classes(&mut self, ids: &[u32]) -> Result<()> {
        super::retire_classes_impl(self.service.as_mut(), &mut self.metrics, ids)
    }

    /// Which training artifact this sampler uses: the Quadratic baseline
    /// optimizes the absolute-softmax loss (paper §4.1).
    #[cfg(feature = "pjrt")]
    fn train_entry(&self) -> String {
        match self.cfg.sampler.kind {
            SamplerKind::Full => self.artifact("train_full"),
            // The absolute-softmax loss ([12]'s pairing for the quadratic
            // kernel) is opt-in; see SamplerConfig::absolute.
            SamplerKind::Quadratic if self.cfg.sampler.absolute => {
                self.artifact("train_sampled_abs")
            }
            _ => self.artifact("train_sampled"),
        }
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = Instant::now();
        let shapes = self.shapes.clone();
        let total_steps = step_cap()
            .map(|c| c.min(self.cfg.train.steps))
            .unwrap_or(self.cfg.train.steps);
        let bsz = shapes.batch;

        // Bounded prefetch of training batches (producer thread).
        let corpus = Arc::clone(&self.corpus);
        let (seq_len, depth) = (shapes.seq_len, self.cfg.train.pipeline_depth);
        let base_seed = self.cfg.data.seed;
        let prefetcher = crate::exec::Prefetcher::spawn(
            depth,
            Some(total_steps),
            move |i| {
                // Re-derive the batch for global step i: epoch-major order.
                let windows = corpus.train.len() - seq_len;
                let steps_per_epoch = (windows / bsz).max(1);
                let epoch = i / steps_per_epoch;
                let within = i % steps_per_epoch;
                corpus
                    .batches(
                        Split::Train,
                        seq_len,
                        bsz,
                        base_seed ^ (epoch as u64).wrapping_mul(0x9E3779B9),
                    )
                    .nth(within)
                    .expect("batch index out of range")
            },
        );

        let mut ewma = Ewma::new(0.05);
        let mut history = Vec::new();
        let mut step = 0usize;
        while let Some(batch) = prefetcher.next() {
            let loss = self.step(&batch)?;
            let smooth = ewma.record(loss);
            self.metrics.observe("train_loss", loss);
            self.metrics.incr("steps", 1);
            step += 1;

            if step % self.cfg.train.eval_every == 0 || step == total_steps {
                let (eval_loss, ppl) = self.evaluate()?;
                let windows = self.corpus.train.len() - shapes.seq_len;
                history.push(EvalPoint {
                    step,
                    epoch: step as f64 * bsz as f64 / windows as f64,
                    train_loss: smooth,
                    eval_loss,
                    metric: ppl,
                });
            }
            if step >= total_steps {
                break;
            }
        }
        let stats = &prefetcher.stats();
        self.metrics.incr(
            "pipeline_producer_stalls",
            stats.producer_stalls.load(std::sync::atomic::Ordering::Relaxed),
        );
        self.metrics.incr(
            "pipeline_consumer_stalls",
            stats.consumer_stalls.load(std::sync::atomic::Ordering::Relaxed),
        );
        if let Some(svc) = &self.service {
            svc.record_serving_metrics(&mut self.metrics);
        }

        if let Some(dir) = self.cfg.train.checkpoint_dir.clone() {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("mkdir {dir}"))?;
            let path = std::path::Path::new(&dir)
                .join(format!("{}_{}.ckpt", self.prefix, self.sampler_name()));
            self.params.save(&path)?;
        }

        let last = history.last().cloned().unwrap_or(EvalPoint {
            step,
            epoch: 0.0,
            train_loss: f64::NAN,
            eval_loss: f64::NAN,
            metric: f64::NAN,
        });
        Ok(TrainReport {
            sampler: self.sampler_name().to_string(),
            history,
            final_metric: last.metric,
            final_eval_loss: last.eval_loss,
            steps_run: step,
            wall_seconds: t0.elapsed().as_secs_f64(),
            metrics: self.metrics.to_json(),
        })
    }

    fn sampler_name(&self) -> &'static str {
        match &self.service {
            Some(s) => s.name(),
            None => "full",
        }
    }

    /// One optimizer step; returns the training loss.
    fn step(&mut self, batch: &LmBatch) -> Result<f64> {
        if self.runtime.is_native() {
            let loss = if self.cfg.sampler.kind == SamplerKind::Full {
                self.native_step_full(batch)?
            } else {
                self.native_step_sampled(batch)?
            };
            self.flush_growths();
            Ok(loss)
        } else {
            self.pjrt_step(batch)
        }
    }

    /// Publish any scratch-buffer capacity growth since the last step as
    /// the `scratch_growths` counter: it moves during warmup (first step
    /// per shape) and must stay flat afterwards — the zero-steady-state-
    /// allocation invariant, machine-checked by `integration_trainer`.
    fn flush_growths(&mut self) {
        if let Some(nat) = &mut self.native {
            let total = nat.growths();
            let delta = total - nat.reported_growths;
            if delta > 0 {
                self.metrics.incr("scratch_growths", delta);
                nat.reported_growths = total;
            }
        }
    }

    /// The fused native sampled step: blocked LSTM forward → batched
    /// negative draw → one-pass fused loss/grad kernel → BPTT backward →
    /// sparse/dense optimizer updates → batched tree propagation. No
    /// `bsz×m` intermediates, no per-step data-plane allocations.
    fn native_step_sampled(&mut self, batch: &LmBatch) -> Result<f64> {
        let LmShapes { d, hidden: h, seq_len: l, batch: bsz, tau, .. } =
            self.shapes;
        let absolute = self.cfg.sampler.kind == SamplerKind::Quadratic
            && self.cfg.sampler.absolute;
        let stale = self.stale_sampling && !self.prev_query.is_empty();
        let nat = self.native.as_mut().expect("native step without state");
        let NativeLm {
            lm,
            fused,
            emb_agg,
            cls_agg,
            tgt_emb,
            neg_emb,
            upd_buf,
            stale_q,
            gather_growths,
            ..
        } = nat;

        // 1. Load context embeddings into the step's blocked layout.
        let t_gather = Instant::now();
        lm.begin(bsz, l, d, h);
        lm.load_rows(&self.params.get(EMB).data, &batch.contexts);
        self.metrics.record_duration("gather", t_gather.elapsed());

        // 2. Encoder forward: the sampling queries come straight out of
        //    the step's own forward pass — no separate encode round.
        let t_fwd = Instant::now();
        lm.forward(
            &self.params.get(WX).data,
            &self.params.get(WH).data,
            &self.params.get(BIAS).data,
            &self.params.get(PROJ).data,
        );
        let fwd_time = t_fwd.elapsed();

        // 3. One batched draw serves the whole step: shared negatives
        //    drawn from the batch's per-example queries (round-robin slot
        //    ownership, exact per-slot probabilities), masks batch-wide.
        //    Stale mode reuses the previous step's pooled query instead
        //    (replicating it would only multiply φ work on equal rows).
        let t_sample = Instant::now();
        let queries: &Matrix = if stale {
            if stale_q.cols() != d {
                *stale_q = Matrix::zeros(1, d);
                *gather_growths += 1;
            }
            stale_q.row_mut(0).copy_from_slice(&self.prev_query);
            &*stale_q
        } else {
            &lm.u
        };
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(queries, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        // 4. Gather class rows into reusable scratch and run the fused
        //    loss+grad kernel, then BPTT back through the LSTM.
        let t_loss = Instant::now();
        {
            let cls = self.params.get(CLS);
            if gather_rows_into(&cls.data, d, &batch.targets, tgt_emb) {
                *gather_growths += 1;
            }
            if gather_rows_into(&cls.data, d, &pack.ids, neg_emb) {
                *gather_growths += 1;
            }
        }
        let loss = fused.run(
            &mut lm.u,
            tgt_emb,
            neg_emb,
            &pack.adjust,
            &pack.mask,
            tau,
            absolute,
        ) as f64;
        lm.backward(
            &self.params.get(WX).data,
            &self.params.get(WH).data,
            &self.params.get(PROJ).data,
            &fused.d_q,
        );
        self.metrics.record_duration("execute", fwd_time + t_loss.elapsed());

        // 5. Optimizer updates: dense LSTM/projection blocks, then the
        //    sparse embedding tables through the reusable aggregators.
        let t_opt = Instant::now();
        for (block, grad) in [
            (WX, &lm.dwx),
            (WH, &lm.dwh),
            (BIAS, &lm.db),
            (PROJ, &lm.dproj),
        ] {
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, grad);
        }
        emb_agg.begin(d);
        for r in 0..bsz {
            for t in 0..l {
                emb_agg.add(batch.contexts[r * l + t], lm.d_x_row(r, t));
            }
        }
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(
                EMB,
                &mut param.data,
                d,
                emb_agg.rows(),
                emb_agg.grads(),
            );
        }
        cls_agg.begin(d);
        for (r, &t) in batch.targets.iter().enumerate() {
            cls_agg.add(t, &fused.d_tgt[r * d..(r + 1) * d]);
        }
        for (j, &id) in pack.ids.iter().enumerate() {
            cls_agg.add(id, &fused.d_neg[j * d..(j + 1) * d]);
        }
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_rows(
                CLS,
                &mut param.data,
                d,
                cls_agg.rows(),
                cls_agg.grads(),
            );
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // 6. Propagate updated class embeddings to the sampling tree as
        //    one batch: φ recomputation collapses into two gemms and
        //    sharded trees absorb disjoint shards in parallel. The row
        //    buffer round-trips through the Matrix so its capacity is
        //    reused next step.
        let t_tree = Instant::now();
        {
            let cls = self.params.get(CLS);
            let cap0 = upd_buf.capacity();
            upd_buf.clear();
            for &r in cls_agg.rows() {
                upd_buf.extend_from_slice(&cls.data[r * d..(r + 1) * d]);
            }
            if upd_buf.capacity() > cap0 {
                *gather_growths += 1;
            }
        }
        let upd =
            Matrix::from_vec(cls_agg.rows().len(), d, std::mem::take(upd_buf));
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(cls_agg.rows(), &upd);
        *upd_buf = upd.into_vec();
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        self.metrics.incr("tree_updates", cls_agg.rows().len() as u64);

        if self.stale_sampling {
            self.prev_query =
                mean_query_from_rows(self.params.get(CLS), &batch.targets, d);
        }
        Ok(loss)
    }

    /// Native full-softmax step (FULL baseline): same LSTM forward/BPTT,
    /// with the one-pass full loss over the whole class table.
    fn native_step_full(&mut self, batch: &LmBatch) -> Result<f64> {
        let LmShapes { n, d, hidden: h, seq_len: l, batch: bsz, tau, .. } =
            self.shapes;
        let normalize = self.cfg.model.normalize && !self.unnormalized;
        let nat = self.native.as_mut().expect("native step without state");
        let NativeLm { lm, full, emb_agg, .. } = nat;

        let t_gather = Instant::now();
        lm.begin(bsz, l, d, h);
        lm.load_rows(&self.params.get(EMB).data, &batch.contexts);
        self.metrics.record_duration("gather", t_gather.elapsed());

        let t_exec = Instant::now();
        lm.forward(
            &self.params.get(WX).data,
            &self.params.get(WH).data,
            &self.params.get(BIAS).data,
            &self.params.get(PROJ).data,
        );
        // Re-prepare the normalized class table every step — the
        // optimizer moved it.
        full.prepare_classes(
            &self.params.get(CLS).data[..n * d],
            n,
            d,
            normalize,
        );
        let loss = full.forward(&mut lm.u, &batch.targets, tau) as f64;
        full.backward(&lm.u, &batch.targets, tau);
        lm.backward(
            &self.params.get(WX).data,
            &self.params.get(WH).data,
            &self.params.get(PROJ).data,
            &full.d_q,
        );
        self.metrics.record_duration("execute", t_exec.elapsed());

        let t_opt = Instant::now();
        for (block, grad) in [
            (WX, &lm.dwx),
            (WH, &lm.dwh),
            (BIAS, &lm.db),
            (PROJ, &lm.dproj),
        ] {
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, grad);
        }
        emb_agg.begin(d);
        for r in 0..bsz {
            for t in 0..l {
                emb_agg.add(batch.contexts[r * l + t], lm.d_x_row(r, t));
            }
        }
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(
                EMB,
                &mut param.data,
                d,
                emb_agg.rows(),
                emb_agg.grads(),
            );
        }
        {
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &full.d_cls);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());
        Ok(loss)
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_step(&mut self, batch: &LmBatch) -> Result<f64> {
        if self.cfg.sampler.kind == SamplerKind::Full {
            self.pjrt_step_full(batch)
        } else {
            self.pjrt_step_sampled(batch)
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_step(&mut self, _batch: &LmBatch) -> Result<f64> {
        anyhow::bail!(
            "non-native runtime in a binary built without the `pjrt` \
             cargo feature"
        )
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_step_sampled(&mut self, batch: &LmBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, seq_len, d, m) = (s.batch, s.seq_len, s.d, s.m);

        // 1. Gather context embeddings.
        let t_gather = Instant::now();
        let ctx_emb =
            gather_rows(&self.params.get(EMB).data, d, &batch.contexts);
        self.metrics.record_duration("gather", t_gather.elapsed());

        // 2. Per-example query rows for sampling: encoder pass (or, in
        //    stale mode, a single-row pool — replicating the stale query
        //    would only multiply φ work on identical rows).
        let t_sample = Instant::now();
        let queries: Matrix = if self.stale_sampling
            && !self.prev_query.is_empty()
        {
            Matrix::from_vec(1, d, self.prev_query.clone())
        } else {
            let enc = self.runtime.get(&self.artifact("encode"))?;
            let outs = enc.run(&[
                HostTensor::f32(&[bsz, seq_len, d], ctx_emb.clone()),
                self.block_tensor(WX),
                self.block_tensor(WH),
                self.block_tensor(BIAS),
                self.block_tensor(PROJ),
            ])?;
            Matrix::from_vec(bsz, d, outs[0].as_f32().to_vec())
        };

        // 3. One batched draw serves the whole step.
        let svc = self.service.as_mut().expect("sampled step without service");
        let pack = svc.draw_batch(&queries, &batch.targets);
        self.metrics
            .incr("accidental_hits", pack.accidental_hits as u64);
        self.metrics.record_duration("sample", t_sample.elapsed());

        // 4. Gather class rows and execute the train artifact.
        let t_exec = Instant::now();
        let tgt_emb = gather_rows(&self.params.get(CLS).data, d, &batch.targets);
        let neg_emb = gather_rows(&self.params.get(CLS).data, d, &pack.ids);
        let exe = self.runtime.get(&self.train_entry())?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
            self.block_tensor(WX),
            self.block_tensor(WH),
            self.block_tensor(BIAS),
            self.block_tensor(PROJ),
            HostTensor::f32(&[bsz, d], tgt_emb),
            HostTensor::f32(&[m, d], neg_emb),
            HostTensor::f32(&[m], pack.adjust.clone()),
            HostTensor::f32(&[bsz, m], pack.mask.clone()),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        // 5. Optimizer updates.
        let t_opt = Instant::now();
        for (block, out_idx) in [(WX, 2), (WH, 3), (BIAS, 4), (PROJ, 5)] {
            let grad = outs[out_idx].as_f32().to_vec();
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, &grad);
        }
        let (rows, grads) = aggregate_rows(&batch.contexts, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(EMB, &mut param.data, d, &rows, &grads);
        }
        let mut cls_ids: Vec<u32> = batch.targets.clone();
        cls_ids.extend_from_slice(&pack.ids);
        let mut cls_grads: Vec<f32> = outs[6].as_f32().to_vec();
        cls_grads.extend_from_slice(outs[7].as_f32());
        let (crow, cgrads) = aggregate_rows(&cls_ids, &cls_grads, d);
        {
            let param = self.params.get_mut(CLS);
            self.optimizer
                .update_rows(CLS, &mut param.data, d, &crow, &cgrads);
        }
        self.metrics.record_duration("optimize", t_opt.elapsed());

        // 6. Propagate updated class embeddings to the sampling tree.
        let t_tree = Instant::now();
        let cls_block = self.params.get(CLS);
        let crow_u32: Vec<u32> = crow.iter().map(|&r| r as u32).collect();
        let upd = Matrix::from_vec(
            crow.len(),
            d,
            gather_rows(&cls_block.data, d, &crow_u32),
        );
        let svc = self.service.as_mut().unwrap();
        svc.update_classes(&crow, &upd);
        self.metrics.record_duration("tree_update", t_tree.elapsed());
        self.metrics.incr("tree_updates", crow.len() as u64);

        if self.stale_sampling {
            self.prev_query =
                mean_query_from_rows(self.params.get(CLS), &batch.targets, d);
        }
        Ok(loss)
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_step_full(&mut self, batch: &LmBatch) -> Result<f64> {
        let s = &self.shapes;
        let (bsz, seq_len, d, n) = (s.batch, s.seq_len, s.d, s.n);
        let ctx_emb =
            gather_rows(&self.params.get(EMB).data, d, &batch.contexts);
        let targets: Vec<i32> =
            batch.targets.iter().map(|&t| t as i32).collect();
        let t_exec = Instant::now();
        let exe = self.runtime.get(&self.artifact("train_full"))?;
        let outs = exe.run(&[
            HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
            self.block_tensor(WX),
            self.block_tensor(WH),
            self.block_tensor(BIAS),
            self.block_tensor(PROJ),
            self.block_tensor_rows(CLS, n),
            HostTensor::i32(&[bsz], targets),
        ])?;
        self.metrics.record_duration("execute", t_exec.elapsed());
        let loss = outs[0].scalar() as f64;

        for (block, out_idx) in [(WX, 2), (WH, 3), (BIAS, 4), (PROJ, 5)] {
            let grad = outs[out_idx].as_f32().to_vec();
            let param = self.params.get_mut(block);
            self.optimizer.update_dense(block, &mut param.data, &grad);
        }
        let (rows, grads) = aggregate_rows(&batch.contexts, outs[1].as_f32(), d);
        {
            let param = self.params.get_mut(EMB);
            self.optimizer.update_rows(EMB, &mut param.data, d, &rows, &grads);
        }
        {
            let grad = outs[6].as_f32().to_vec();
            let param = self.params.get_mut(CLS);
            self.optimizer.update_dense(CLS, &mut param.data, &grad);
        }
        Ok(loss)
    }

    /// Full-softmax validation loss + perplexity over `eval_batches`.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        if self.runtime.is_native() {
            self.native_evaluate()
        } else {
            self.pjrt_evaluate()
        }
    }

    /// Native eval: prepare the normalized class table once, then score
    /// every validation batch with the streaming full-softmax kernel.
    fn native_evaluate(&mut self) -> Result<(f64, f64)> {
        let LmShapes { n, d, hidden: h, seq_len: l, batch: bsz, tau, .. } =
            self.shapes;
        let normalize = self.cfg.model.normalize && !self.unnormalized;
        let t_eval = Instant::now();
        let nat = self.native.as_mut().expect("native eval without state");
        let NativeLm { lm, full, .. } = nat;
        // Fixed-shape view: score the base vocabulary (exactly the
        // corpus's label space) even after extend_vocab grew the table.
        full.prepare_classes(
            &self.params.get(CLS).data[..n * d],
            n,
            d,
            normalize,
        );
        let mut total = 0.0;
        let mut count = 0usize;
        for batch in self
            .corpus
            .batches(Split::Valid, l, bsz, 0)
            .take(self.cfg.train.eval_batches)
        {
            lm.begin(bsz, l, d, h);
            lm.load_rows(&self.params.get(EMB).data, &batch.contexts);
            lm.forward(
                &self.params.get(WX).data,
                &self.params.get(WH).data,
                &self.params.get(BIAS).data,
                &self.params.get(PROJ).data,
            );
            total += full.forward(&mut lm.u, &batch.targets, tau) as f64;
            count += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(count > 0, "no validation batches");
        let mean = total / count as f64;
        Ok((mean, perplexity(mean)))
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_evaluate(&mut self) -> Result<(f64, f64)> {
        let s = &self.shapes;
        let (bsz, seq_len, d) = (s.batch, s.seq_len, s.d);
        let exe = self.runtime.get(&self.artifact("eval"))?;
        let mut total = 0.0;
        let mut count = 0usize;
        let t_eval = Instant::now();
        for batch in self
            .corpus
            .batches(Split::Valid, seq_len, bsz, 0)
            .take(self.cfg.train.eval_batches)
        {
            let ctx_emb =
                gather_rows(&self.params.get(EMB).data, d, &batch.contexts);
            let targets: Vec<i32> =
                batch.targets.iter().map(|&t| t as i32).collect();
            let outs = exe.run(&[
                HostTensor::f32(&[bsz, seq_len, d], ctx_emb),
                self.block_tensor(WX),
                self.block_tensor(WH),
                self.block_tensor(BIAS),
                self.block_tensor(PROJ),
                // Fixed-shape view: the compiled eval scores the base
                // vocabulary even after extend_vocab grew the table.
                self.block_tensor_rows(CLS, self.shapes.n),
                HostTensor::i32(&[bsz], targets),
            ])?;
            total += outs[0].scalar() as f64;
            count += 1;
        }
        self.metrics.record_duration("eval", t_eval.elapsed());
        anyhow::ensure!(count > 0, "no validation batches");
        let mean = total / count as f64;
        Ok((mean, perplexity(mean)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_evaluate(&mut self) -> Result<(f64, f64)> {
        anyhow::bail!(
            "non-native runtime in a binary built without the `pjrt` \
             cargo feature"
        )
    }

    #[cfg(feature = "pjrt")]
    fn block_tensor(&self, id: usize) -> HostTensor {
        let b = self.params.get(id);
        HostTensor::f32(&b.shape, b.data.clone())
    }

    /// First `rows` rows of a 2-D block — the compiled artifacts' fixed
    /// shape view of a table that may have grown past it via
    /// [`LmTrainer::extend_vocab`].
    #[cfg(feature = "pjrt")]
    fn block_tensor_rows(&self, id: usize, rows: usize) -> HostTensor {
        super::block_rows_tensor(&self.params, id, rows)
    }
}

/// Normalized copy of the class-embedding block as a Matrix.
fn normalized_classes(params: &ParamStore, id: usize) -> Matrix {
    let b = params.get(id);
    Matrix::from_vec(b.rows(), b.cols(), b.data.clone()).l2_normalized_rows()
}

/// Gather `ids` rows from a flat `rows × dim` table into a fresh Vec
/// (the pjrt paths; the native paths use
/// [`crate::runtime::native::gather_rows_into`] over reusable scratch).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) fn gather_rows(table: &[f32], dim: usize, ids: &[u32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(ids.len() * dim);
    for &id in ids {
        let s = id as usize * dim;
        out.extend_from_slice(&table[s..s + dim]);
    }
    out
}

/// Normalized mean of the batch's h rows — the pre-batch-pipeline shared
/// sampling query, kept for diagnostics and A/B comparisons against
/// per-example batch queries.
#[allow(dead_code)]
pub(crate) fn mean_query(h: &[f32], bsz: usize, d: usize) -> Vec<f32> {
    let mut q = vec![0.0f32; d];
    for b in 0..bsz {
        for (qi, &hi) in q.iter_mut().zip(&h[b * d..(b + 1) * d]) {
            *qi += hi;
        }
    }
    l2_normalize(&mut q);
    q
}

fn mean_query_from_rows(
    block: &crate::model::Block,
    ids: &[u32],
    d: usize,
) -> Vec<f32> {
    let mut q = vec![0.0f32; d];
    for &id in ids {
        for (qi, &v) in q.iter_mut().zip(block.row(id as usize)) {
            *qi += v;
        }
    }
    l2_normalize(&mut q);
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_rows_layout() {
        let table = vec![0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0];
        let out = gather_rows(&table, 2, &[2, 0]);
        assert_eq!(out, vec![20.0, 21.0, 0.0, 1.0]);
    }

    #[test]
    fn mean_query_is_normalized() {
        let h = vec![1.0f32, 0.0, 0.0, 1.0]; // two 2-d rows
        let q = mean_query(&h, 2, 2);
        let norm: f32 = q.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        assert!((q[0] - q[1]).abs() < 1e-6);
    }
}
