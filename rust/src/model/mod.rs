//! Parameter store: named, shaped f32 blocks owned by the Rust
//! coordinator. The PJRT executables are pure functions — parameters are
//! passed in and gradients returned every step — so this store is the
//! single source of truth for model state (L3 owns state; DESIGN.md §1).
//!
//! Includes binary checkpointing (save/load with shape validation) and
//! L2-normalized row views for the paper's normalized-embedding regime.

use crate::linalg::l2_normalize;
use crate::rng::Rng;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// One named parameter block.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Block {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Rows/cols for 2-D blocks (embedding tables).
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows(): block {} is not 2-D", self.name);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols(): block {} is not 2-D", self.name);
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// L2-normalize every row in place (paper §3.2 normalized embeddings).
    pub fn normalize_rows(&mut self) {
        let c = self.cols();
        for chunk in self.data.chunks_mut(c) {
            l2_normalize(chunk);
        }
    }

    /// Append rows to a 2-D block (dynamic-vocabulary growth: the class
    /// table grows in place when the sampler's universe is extended;
    /// `Vec` doubling amortizes the copy). Width must match.
    pub fn append_rows(&mut self, extra: &crate::linalg::Matrix) {
        assert_eq!(
            self.cols(),
            extra.cols(),
            "append_rows({}): width mismatch",
            self.name
        );
        self.data.extend_from_slice(extra.data());
        self.shape[0] += extra.rows();
    }
}

/// Ordered collection of parameter blocks. Block order is the calling
/// convention of the AOT executables (see `artifacts/manifest.json`).
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    blocks: Vec<Block>,
    index: BTreeMap<String, usize>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a block initialized with gaussian(0, std) entries.
    pub fn add_randn(
        &mut self,
        name: &str,
        shape: &[usize],
        std: f32,
        rng: &mut Rng,
    ) -> usize {
        let numel: usize = shape.iter().product();
        let mut data = vec![0.0f32; numel];
        rng.fill_gaussian_f32(&mut data);
        for v in data.iter_mut() {
            *v *= std;
        }
        self.add(name, shape, data)
    }

    /// Add a zero block.
    pub fn add_zeros(&mut self, name: &str, shape: &[usize]) -> usize {
        let numel: usize = shape.iter().product();
        self.add(name, shape, vec![0.0; numel])
    }

    pub fn add(&mut self, name: &str, shape: &[usize], data: Vec<f32>) -> usize {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "ParamStore::add({name}): data/shape mismatch"
        );
        assert!(
            !self.index.contains_key(name),
            "ParamStore: duplicate block '{name}'"
        );
        let id = self.blocks.len();
        self.index.insert(name.to_string(), id);
        self.blocks.push(Block {
            name: name.to_string(),
            shape: shape.to_vec(),
            data,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.blocks.iter().map(|b| b.numel()).sum()
    }

    pub fn get(&self, id: usize) -> &Block {
        &self.blocks[id]
    }

    pub fn get_mut(&mut self, id: usize) -> &mut Block {
        &mut self.blocks[id]
    }

    pub fn by_name(&self, name: &str) -> Option<&Block> {
        self.index.get(name).map(|&i| &self.blocks[i])
    }

    pub fn by_name_mut(&mut self, name: &str) -> Option<&mut Block> {
        if let Some(&i) = self.index.get(name) {
            Some(&mut self.blocks[i])
        } else {
            None
        }
    }

    pub fn id_of(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Block> {
        self.blocks.iter()
    }

    /// Binary checkpoint format:
    /// magic "RFSM" | u32 version | u32 nblocks | per block:
    /// u32 name_len | name | u32 ndim | u64 dims… | f32 data…
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"RFSM")?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.blocks.len() as u32).to_le_bytes())?;
        for b in &self.blocks {
            f.write_all(&(b.name.len() as u32).to_le_bytes())?;
            f.write_all(b.name.as_bytes())?;
            f.write_all(&(b.shape.len() as u32).to_le_bytes())?;
            for &d in &b.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in &b.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"RFSM" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad checkpoint magic",
            ));
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        if version != 1 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unsupported checkpoint version {version}"),
            ));
        }
        f.read_exact(&mut u32b)?;
        let nblocks = u32::from_le_bytes(u32b) as usize;
        let mut store = ParamStore::new();
        for _ in 0..nblocks {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            let mut name_buf = vec![0u8; name_len];
            f.read_exact(&mut name_buf)?;
            let name = String::from_utf8(name_buf).map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "bad block name",
                )
            })?;
            f.read_exact(&mut u32b)?;
            let ndim = u32::from_le_bytes(u32b) as usize;
            let mut shape = Vec::with_capacity(ndim);
            let mut u64b = [0u8; 8];
            for _ in 0..ndim {
                f.read_exact(&mut u64b)?;
                shape.push(u64::from_le_bytes(u64b) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0.0f32; numel];
            let mut f32b = [0u8; 4];
            for v in data.iter_mut() {
                f.read_exact(&mut f32b)?;
                *v = f32::from_le_bytes(f32b);
            }
            store.add(&name, &shape, data);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_lookup() {
        let mut rng = Rng::seeded(141);
        let mut s = ParamStore::new();
        let id = s.add_randn("emb", &[10, 4], 0.1, &mut rng);
        assert_eq!(s.id_of("emb"), Some(id));
        assert_eq!(s.get(id).rows(), 10);
        assert_eq!(s.get(id).cols(), 4);
        assert_eq!(s.total_params(), 40);
        assert!(s.by_name("missing").is_none());
    }

    #[test]
    #[should_panic(expected = "duplicate block")]
    fn duplicate_name_panics() {
        let mut s = ParamStore::new();
        s.add_zeros("x", &[2]);
        s.add_zeros("x", &[2]);
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut rng = Rng::seeded(142);
        let mut s = ParamStore::new();
        s.add_randn("c", &[7, 5], 2.0, &mut rng);
        s.by_name_mut("c").unwrap().normalize_rows();
        let b = s.by_name("c").unwrap();
        for i in 0..7 {
            let n: f32 = b.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn checkpoint_round_trip() {
        let mut rng = Rng::seeded(143);
        let mut s = ParamStore::new();
        s.add_randn("emb", &[6, 3], 0.5, &mut rng);
        s.add_randn("proj", &[3, 4], 0.5, &mut rng);
        s.add_zeros("bias", &[4]);
        let dir = std::env::temp_dir().join("rfsm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.ckpt");
        s.save(&p).unwrap();
        let loaded = ParamStore::load(&p).unwrap();
        assert_eq!(loaded.len(), 3);
        for (a, b) in s.iter().zip(loaded.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("rfsm_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(ParamStore::load(&p).is_err());
    }
}
