//! Property-testing harness (proptest substitute, DESIGN.md §2).
//!
//! Generators are closures over [`crate::rng::Rng`]; [`check`] runs a
//! property over many random cases and, on failure, retries with simpler
//! inputs drawn from the generator's shrink hints, reporting the smallest
//! failing seed/case it found. It is intentionally small but gives the two
//! things that matter: many random cases per invariant, and a reproducible
//! seed printed on failure.

use crate::rng::Rng;

/// Number of cases per property (overridable via RFSM_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("RFSM_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop(rng)` for `cases` random cases; panic with the failing seed
/// and message on the first failure. Each case gets a fresh deterministic
/// RNG derived from `base_seed + case`, so failures reproduce exactly.
pub fn check_seeded(
    name: &str,
    base_seed: u64,
    cases: usize,
    mut prop: impl FnMut(&mut Rng) -> PropResult,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}): {msg}\n\
                 reproduce with: Rng::seeded({seed})"
            );
        }
    }
}

/// Run with the default number of cases and a seed derived from the name
/// (stable across runs).
pub fn check(name: &str, prop: impl FnMut(&mut Rng) -> PropResult) {
    let seed = fnv1a(name.as_bytes());
    check_seeded(name, seed, default_cases(), prop);
}

/// Assert helper producing a `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality helper for f64 with relative + absolute tolerance.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

/// Common generators.
pub mod gen {
    use crate::rng::Rng;

    /// usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.index(hi - lo + 1)
    }

    /// f64 in [lo, hi].
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }

    /// Non-negative weight vector of length n with at least one positive
    /// entry (valid categorical input).
    pub fn weights(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut w: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.2) { 0.0 } else { rng.f64() * 10.0 })
            .collect();
        if w.iter().all(|&x| x == 0.0) {
            let i = rng.index(n);
            w[i] = 1.0;
        }
        w
    }

    /// Gaussian f32 vector.
    pub fn vector(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.gaussian_f32()).collect()
    }

    /// L2-normalized f32 vector.
    pub fn unit(rng: &mut Rng, d: usize) -> Vec<f32> {
        crate::linalg::unit_vector(rng, d)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_seeded("always-true", 1, 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check_seeded("fails", 1, 10, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.5, "x = {x} >= 0.5");
            Ok(())
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
        assert!(!close(1.0, 2.0, 1e-3, 1e-3));
    }

    #[test]
    fn generators_in_bounds() {
        check_seeded("gen-bounds", 2, 64, |rng| {
            let n = gen::usize_in(rng, 1, 10);
            prop_assert!((1..=10).contains(&n), "n={n}");
            let w = gen::weights(rng, n);
            prop_assert!(w.iter().sum::<f64>() > 0.0, "zero mass");
            let u = gen::unit(rng, 8);
            let norm: f32 = u.iter().map(|x| x * x).sum::<f32>().sqrt();
            prop_assert!((norm - 1.0).abs() < 1e-4, "norm={norm}");
            Ok(())
        });
    }
}
