//! Quantized storage for the sampler's private class-embedding copy.
//!
//! The kernel samplers keep their own copy of the class-embedding
//! table (the "universe" the tree walks over). RF-softmax tolerates
//! approximation by construction — the sampling distribution only has
//! to track `q_i ∝ φ(c_i)ᵀφ(h)` within a bias budget — so this private
//! copy is the one place the crate quantizes aggressively: the
//! opt-in `sampler.quantize` knob stores it in IEEE 754 half precision
//! (`f16`, half the bytes) or `i8` with per-row scales (a quarter of
//! the bytes), and every read dequantizes back to f32 before the SIMD
//! kernels run. Quantization happens **on ingest** (build, add,
//! update), and φ is always computed from the *dequantized* stored
//! row, so the tree's interior sums are consistently sums of
//! `φ(deq(quant(c)))` — drift shows up as a slightly perturbed
//! universe, not as tree-internal inconsistency.
//!
//! `f16` conversion is hand-rolled (no new deps): round-to-nearest-even
//! with subnormal and inf/NaN handling. The x86_64 fast path
//! dequantizes rows with `_mm256_cvtph_ps` (F16C) / `_mm256_cvtepi8_epi32`;
//! both are element-wise exact, so SIMD and scalar dequantization
//! produce bit-identical f32 rows and dispatch never perturbs draws
//! within a tier.

use super::simd::{self, SimdTier};
use super::Matrix;

/// How the sampler stores its private class-embedding copy
/// (`sampler.quantize`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantizeKind {
    /// Full f32 rows (the default; byte-identical to the historic
    /// behavior).
    None,
    /// IEEE 754 binary16 rows — half the bytes, ~1e-3 relative error.
    F16,
    /// i8 rows with one f32 scale per row — a quarter of the bytes,
    /// ~1/255 relative error per element.
    I8,
}

impl QuantizeKind {
    /// Parse a `sampler.quantize` config value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(QuantizeKind::None),
            "f16" => Some(QuantizeKind::F16),
            "i8" => Some(QuantizeKind::I8),
            _ => None,
        }
    }

    /// The config-file / BENCH-JSON spelling.
    pub fn name(&self) -> &'static str {
        match self {
            QuantizeKind::None => "none",
            QuantizeKind::F16 => "f16",
            QuantizeKind::I8 => "i8",
        }
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even. Overflow goes
/// to ±inf, tiny values to signed zero/subnormals, NaN stays NaN (quiet
/// bit forced so the payload never collapses to inf).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN: keep NaN-ness explicit.
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 | ((man >> 13) as u16 & 0x03FF) | 0x0200
        };
    }
    let half_exp = exp - 127 + 15;
    if half_exp >= 0x1F {
        // Too large for half precision: round to infinity.
        return sign | 0x7C00;
    }
    if half_exp <= 0 {
        // Subnormal (or zero) in half precision.
        let shift = 14 - half_exp; // bits of mantissa dropped beyond 10
        if shift > 24 {
            return sign; // rounds to signed zero
        }
        let full_man = man | 0x0080_0000; // implicit leading one
        let half_man = (full_man >> shift) as u16;
        // Round to nearest even on the dropped bits.
        let round_bit = 1u32 << (shift - 1);
        if (full_man & round_bit) != 0
            && (full_man & (3 * round_bit - 1)) != 0
        {
            return sign | (half_man + 1);
        }
        return sign | half_man;
    }
    let half = sign | ((half_exp as u16) << 10) | ((man >> 13) as u16);
    // RNE on the 13 dropped mantissa bits; the +1 on the assembled u16
    // deliberately carries into the exponent (and on to inf) when the
    // mantissa overflows.
    let round_bit = 1u32 << 12;
    if (man & round_bit) != 0 && (man & (3 * round_bit - 1)) != 0 {
        half + 1
    } else {
        half
    }
}

/// IEEE 754 binary16 bits → f32 (exact: every f16 value is
/// representable in f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign);
        }
        // Subnormal: man · 2⁻²⁴, exact in f32.
        let mag = man as f32 * f32::from_bits(0x3380_0000);
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Quantize one row to i8 with a shared scale; returns the scale.
/// Zero rows get scale 1.0 so dequantization is exact for them.
fn quantize_i8_row(row: &[f32], out: &mut [i8]) -> f32 {
    let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    for (o, &v) in out.iter_mut().zip(row.iter()) {
        *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Scalar f16 row dequantization (the reference the SIMD kernel must
/// match bit-for-bit).
fn dequant_f16_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &h) in dst.iter_mut().zip(src.iter()) {
        *d = f16_to_f32(h);
    }
}

fn dequant_i8_scalar(src: &[i8], scale: f32, dst: &mut [f32]) {
    for (d, &q) in dst.iter_mut().zip(src.iter()) {
        *d = q as f32 * scale;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// 8-at-a-time f16 → f32 via F16C. `_mm256_cvtph_ps` implements the
    /// exact IEEE conversion, so this is bit-identical to the scalar
    /// path.
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn dequant_f16(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let sp = src.as_ptr();
        let dp = dst.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let h = _mm_loadu_si128(sp.add(i) as *const __m128i);
            _mm256_storeu_ps(dp.add(i), _mm256_cvtph_ps(h));
            i += 8;
        }
        while i < n {
            *dp.add(i) = super::f16_to_f32(*sp.add(i));
            i += 1;
        }
    }

    /// 8-at-a-time i8 → f32·scale. Widening conversion is exact and the
    /// single multiply rounds identically to scalar, so this too is
    /// bit-identical to the scalar path.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_i8(src: &[i8], scale: f32, dst: &mut [f32]) {
        let n = src.len();
        let sp = src.as_ptr();
        let dp = dst.as_mut_ptr();
        let vs = _mm256_set1_ps(scale);
        let mut i = 0usize;
        while i + 8 <= n {
            let q = _mm_loadl_epi64(sp.add(i) as *const __m128i);
            let wide = _mm256_cvtepi8_epi32(q);
            let f = _mm256_cvtepi32_ps(wide);
            _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(f, vs));
            i += 8;
        }
        while i < n {
            *dp.add(i) = *sp.add(i) as f32 * scale;
            i += 1;
        }
    }
}

fn dequant_f16(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if simd::tier() == SimdTier::Avx2 {
        // SAFETY: Avx2 tier ⇒ runtime-detected avx2+f16c.
        unsafe { x86::dequant_f16(src, dst) };
        return;
    }
    let _ = simd::tier(); // keep dispatch cost symmetric off-x86
    dequant_f16_scalar(src, dst);
}

fn dequant_i8(src: &[i8], scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if simd::tier() == SimdTier::Avx2 {
        // SAFETY: Avx2 tier ⇒ runtime-detected avx2.
        unsafe { x86::dequant_i8(src, scale, dst) };
        return;
    }
    let _ = simd::tier();
    dequant_i8_scalar(src, scale, dst);
}

/// The sampler's class-embedding table in its configured precision.
///
/// Row-major like [`Matrix`]; `push_row`/`set_row` quantize on ingest,
/// `row_into`/`dequantized` hand back f32 for the compute kernels.
#[derive(Clone, Debug)]
pub enum ClassStore {
    /// Plain f32 rows (wraps the historic `Matrix` layout).
    F32(Matrix),
    /// binary16 rows.
    F16 { cols: usize, data: Vec<u16> },
    /// i8 rows with one f32 scale per row.
    I8 { cols: usize, data: Vec<i8>, scales: Vec<f32> },
}

impl ClassStore {
    /// Quantize an f32 table into the requested representation.
    pub fn from_matrix(m: &Matrix, kind: QuantizeKind) -> Self {
        match kind {
            QuantizeKind::None => ClassStore::F32(m.clone()),
            QuantizeKind::F16 => {
                let data =
                    m.data().iter().map(|&v| f32_to_f16(v)).collect();
                ClassStore::F16 { cols: m.cols(), data }
            }
            QuantizeKind::I8 => {
                let (rows, cols) = (m.rows(), m.cols());
                let mut data = vec![0i8; rows * cols];
                let mut scales = Vec::with_capacity(rows);
                for r in 0..rows {
                    let s = quantize_i8_row(
                        m.row(r),
                        &mut data[r * cols..(r + 1) * cols],
                    );
                    scales.push(s);
                }
                ClassStore::I8 { cols, data, scales }
            }
        }
    }

    /// Which representation this store uses.
    pub fn kind(&self) -> QuantizeKind {
        match self {
            ClassStore::F32(_) => QuantizeKind::None,
            ClassStore::F16 { .. } => QuantizeKind::F16,
            ClassStore::I8 { .. } => QuantizeKind::I8,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            ClassStore::F32(m) => m.rows(),
            ClassStore::F16 { cols, data } => data.len() / cols,
            ClassStore::I8 { scales, .. } => scales.len(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            ClassStore::F32(m) => m.cols(),
            ClassStore::F16 { cols, .. } => *cols,
            ClassStore::I8 { cols, .. } => *cols,
        }
    }

    /// Append one row, quantizing on ingest.
    pub fn push_row(&mut self, row: &[f32]) {
        match self {
            ClassStore::F32(m) => m.push_row(row),
            ClassStore::F16 { cols, data } => {
                assert_eq!(row.len(), *cols, "push_row: width mismatch");
                data.extend(row.iter().map(|&v| f32_to_f16(v)));
            }
            ClassStore::I8 { cols, data, scales } => {
                assert_eq!(row.len(), *cols, "push_row: width mismatch");
                let base = data.len();
                data.resize(base + *cols, 0);
                let s = quantize_i8_row(row, &mut data[base..]);
                scales.push(s);
            }
        }
    }

    /// Overwrite row `i`, quantizing on ingest.
    pub fn set_row(&mut self, i: usize, row: &[f32]) {
        match self {
            ClassStore::F32(m) => m.row_mut(i).copy_from_slice(row),
            ClassStore::F16 { cols, data } => {
                assert_eq!(row.len(), *cols, "set_row: width mismatch");
                for (d, &v) in data[i * *cols..(i + 1) * *cols]
                    .iter_mut()
                    .zip(row.iter())
                {
                    *d = f32_to_f16(v);
                }
            }
            ClassStore::I8 { cols, data, scales } => {
                assert_eq!(row.len(), *cols, "set_row: width mismatch");
                scales[i] = quantize_i8_row(
                    row,
                    &mut data[i * *cols..(i + 1) * *cols],
                );
            }
        }
    }

    /// Dequantize row `i` into `out` (f32 passes through untouched).
    pub fn row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            ClassStore::F32(m) => out.copy_from_slice(m.row(i)),
            ClassStore::F16 { cols, data } => {
                dequant_f16(&data[i * cols..(i + 1) * cols], out);
            }
            ClassStore::I8 { cols, data, scales } => {
                dequant_i8(
                    &data[i * cols..(i + 1) * cols],
                    scales[i],
                    out,
                );
            }
        }
    }

    /// Materialize the whole table as f32 (used for gemm inputs and
    /// forks; for `None` this is a plain copy).
    pub fn dequantized(&self) -> Matrix {
        match self {
            ClassStore::F32(m) => m.clone(),
            _ => {
                let (rows, cols) = (self.rows(), self.cols());
                let mut out = Matrix::zeros(rows, cols);
                for r in 0..rows {
                    self.row_into(r, out.row_mut(r));
                }
                out
            }
        }
    }

    /// Gather a subset of rows as a dense f32 matrix.
    pub fn gather_rows(&self, ids: &[u32]) -> Matrix {
        let cols = self.cols();
        let mut out = Matrix::zeros(ids.len(), cols);
        for (r, &id) in ids.iter().enumerate() {
            self.row_into(id as usize, out.row_mut(r));
        }
        out
    }

    /// Bytes held by the table payload (what `sampler.quantize` is
    /// buying down).
    pub fn memory_bytes(&self) -> usize {
        match self {
            ClassStore::F32(m) => m.data().len() * 4,
            ClassStore::F16 { data, .. } => data.len() * 2,
            ClassStore::I8 { data, scales, .. } => {
                data.len() + scales.len() * 4
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn parse_and_name_round_trip() {
        for kind in
            [QuantizeKind::None, QuantizeKind::F16, QuantizeKind::I8]
        {
            assert_eq!(QuantizeKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(QuantizeKind::parse("fp8"), None);
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(0.5), 0x3800);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // f16::MAX
        assert_eq!(f32_to_f16(65520.0), 0x7C00); // rounds to +inf
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Smallest subnormal is 2⁻²⁴; half of it ties to even zero.
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_round_trip_is_idempotent_and_close() {
        let mut rng = Rng::seeded(11);
        for _ in 0..2000 {
            let v = rng.gaussian_f32();
            let h = f32_to_f16(v);
            let back = f16_to_f32(h);
            // Within half an f16 ulp (~2⁻¹¹ relative for normals).
            assert!(
                (back - v).abs() <= v.abs() * 1.0e-3 + 1.0e-7,
                "{v} -> {back}"
            );
            // f16 values round-trip exactly.
            assert_eq!(f32_to_f16(back), h);
        }
    }

    #[test]
    fn i8_rows_use_full_range_and_handle_zeros() {
        let row = [0.5f32, -1.0, 0.25, 0.0];
        let mut q = [0i8; 4];
        let scale = quantize_i8_row(&row, &mut q);
        assert_eq!(q[1], -127, "maxabs element must hit the rail");
        let mut back = [0.0f32; 4];
        dequant_i8_scalar(&q, scale, &mut back);
        for (b, v) in back.iter().zip(row.iter()) {
            assert!((b - v).abs() <= scale * 0.5 + 1e-7);
        }
        let zeros = [0.0f32; 4];
        let mut qz = [0i8; 4];
        let sz = quantize_i8_row(&zeros, &mut qz);
        assert_eq!(sz, 1.0);
        assert_eq!(qz, [0, 0, 0, 0]);
    }

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seeded(seed);
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for v in m.row_mut(r) {
                *v = rng.gaussian_f32();
            }
        }
        m
    }

    #[test]
    fn store_round_trips_within_kind_tolerance() {
        let m = random_matrix(17, 29, 23);
        for (kind, tol) in [
            (QuantizeKind::None, 0.0f32),
            (QuantizeKind::F16, 2.0e-3),
            (QuantizeKind::I8, 4.0e-2),
        ] {
            let store = ClassStore::from_matrix(&m, kind);
            assert_eq!(store.kind(), kind);
            assert_eq!(store.rows(), 17);
            assert_eq!(store.cols(), 29);
            let back = store.dequantized();
            for r in 0..17 {
                let scale = m.row(r).iter().fold(0.0f32, |a, &v| {
                    a.max(v.abs())
                });
                for (got, want) in
                    back.row(r).iter().zip(m.row(r).iter())
                {
                    assert!(
                        (got - want).abs() <= tol * scale.max(1.0),
                        "{kind:?} row {r}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn push_and_set_match_from_matrix() {
        let m = random_matrix(9, 16, 31);
        for kind in
            [QuantizeKind::None, QuantizeKind::F16, QuantizeKind::I8]
        {
            let whole = ClassStore::from_matrix(&m, kind);
            let mut grown =
                ClassStore::from_matrix(&Matrix::zeros(0, 16), kind);
            for r in 0..9 {
                grown.push_row(m.row(r));
            }
            let mut buf_a = vec![0.0f32; 16];
            let mut buf_b = vec![0.0f32; 16];
            for r in 0..9 {
                whole.row_into(r, &mut buf_a);
                grown.row_into(r, &mut buf_b);
                assert_eq!(buf_a, buf_b, "{kind:?} push row {r}");
            }
            // Overwriting a row matches quantizing it fresh.
            grown.set_row(4, m.row(7));
            whole.row_into(7, &mut buf_a);
            grown.row_into(4, &mut buf_b);
            assert_eq!(buf_a, buf_b, "{kind:?} set_row");
        }
    }

    #[test]
    fn simd_dequant_matches_scalar_reference() {
        // Compare the dispatched row_into against the pure-scalar
        // converters across awkward lengths; on AVX2 machines this
        // pins the F16C/cvtepi8 kernels to the scalar bit patterns.
        let mut rng = Rng::seeded(47);
        for cols in [1usize, 7, 8, 9, 16, 31, 40] {
            let mut m = Matrix::zeros(3, cols);
            for r in 0..3 {
                for v in m.row_mut(r) {
                    *v = rng.gaussian_f32();
                }
            }
            for kind in [QuantizeKind::F16, QuantizeKind::I8] {
                let store = ClassStore::from_matrix(&m, kind);
                let mut got = vec![0.0f32; cols];
                let mut want = vec![0.0f32; cols];
                for r in 0..3 {
                    store.row_into(r, &mut got);
                    match &store {
                        ClassStore::F16 { cols, data } => {
                            dequant_f16_scalar(
                                &data[r * cols..(r + 1) * cols],
                                &mut want,
                            );
                        }
                        ClassStore::I8 { cols, data, scales } => {
                            dequant_i8_scalar(
                                &data[r * cols..(r + 1) * cols],
                                scales[r],
                                &mut want,
                            );
                        }
                        ClassStore::F32(_) => unreachable!(),
                    }
                    for i in 0..cols {
                        assert_eq!(
                            got[i].to_bits(),
                            want[i].to_bits(),
                            "{kind:?} cols={cols} row {r} elem {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn memory_accounting_tracks_kind() {
        let m = random_matrix(10, 32, 53);
        let f32b = ClassStore::from_matrix(&m, QuantizeKind::None)
            .memory_bytes();
        let f16b = ClassStore::from_matrix(&m, QuantizeKind::F16)
            .memory_bytes();
        let i8b =
            ClassStore::from_matrix(&m, QuantizeKind::I8).memory_bytes();
        assert_eq!(f32b, 10 * 32 * 4);
        assert_eq!(f16b, 10 * 32 * 2);
        assert_eq!(i8b, 10 * 32 + 10 * 4);
    }

    #[test]
    fn gather_rows_dequantizes_selected_ids() {
        let m = random_matrix(12, 8, 67);
        let store = ClassStore::from_matrix(&m, QuantizeKind::F16);
        let picked = store.gather_rows(&[3, 11, 0]);
        assert_eq!(picked.rows(), 3);
        let mut want = vec![0.0f32; 8];
        for (r, &id) in [3u32, 11, 0].iter().enumerate() {
            store.row_into(id as usize, &mut want);
            assert_eq!(picked.row(r), &want[..]);
        }
    }
}
