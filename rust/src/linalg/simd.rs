//! Runtime-dispatched SIMD microkernels for the f32 hot path.
//!
//! The toolchain is pinned to stable Rust (no nightly `std::simd`), so
//! vectorization is explicit `std::arch` intrinsics behind **runtime
//! feature detection**: one binary carries a scalar path (always
//! compiled, the correctness reference), an AVX2+FMA path (x86_64), and
//! a NEON path (aarch64). The tier is detected once per process
//! ([`tier`], cached in a `OnceLock`) and every public entry point here
//! dispatches on it, so callers — [`super::dot`], `Matrix::matmul_nt`,
//! [`super::axpy_rows`], the feature-map gemms — pick up the fast path
//! without caring which machine they run on.
//!
//! Dispatch tiers:
//!
//! * **`avx2`** — requires `avx2 && fma && f16c` together (every AVX2
//!   part since Haswell has all three; one flag also covers the f16
//!   dequantization kernel in [`super::quant`]). 8-wide `_mm256` dot
//!   with 4 independent accumulators, and a register-blocked 4×2
//!   `matmul_nt` microkernel (8 FMA accumulators per tile).
//! * **`neon`** — aarch64 baseline NEON: 4-wide `vfmaq_f32` dot; the
//!   gemm reuses the vector dot per output cell.
//! * **`scalar`** — the portable 4-accumulator loops (what the whole
//!   crate used before dispatch existed). Also forced by setting the
//!   env var `RFSM_FORCE_SCALAR` (any value other than empty or `0`),
//!   which CI uses to exercise both paths on one runner.
//!
//! Numerical contract: `dot`/`matmul_nt_into` may differ from the
//! scalar path in the last ulps (different accumulator shapes ⇒
//! different rounding order); NaN/inf propagate identically. `axpy` is
//! **bit-exact** across tiers — it is element-wise with no
//! reassociation, and the vector paths deliberately use mul+add (not
//! FMA) to keep per-element rounding identical to scalar.

use std::sync::OnceLock;

/// Which instruction-set tier [`tier`] selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable fallback (also the `RFSM_FORCE_SCALAR` override).
    Scalar,
    /// x86_64 with AVX2 + FMA + F16C (runtime-detected).
    Avx2,
    /// aarch64 NEON.
    Neon,
}

static TIER: OnceLock<SimdTier> = OnceLock::new();

/// Whether the given `RFSM_FORCE_SCALAR` value requests the scalar
/// tier. Unset, empty, and `"0"` mean "no"; anything else means "yes".
fn force_scalar_requested(val: Option<&str>) -> bool {
    match val {
        None => false,
        Some(v) => !v.is_empty() && v != "0",
    }
}

fn detect() -> SimdTier {
    let forced = std::env::var("RFSM_FORCE_SCALAR").ok();
    if force_scalar_requested(forced.as_deref()) {
        return SimdTier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // All three ship together on every AVX2 core since Haswell;
        // requiring the trio means one tier flag also covers the F16C
        // dequantization kernels in `linalg::quant`.
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
        {
            return SimdTier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdTier::Neon;
        }
    }
    SimdTier::Scalar
}

/// The dispatch tier for this process (detected once, then cached).
#[inline]
pub fn tier() -> SimdTier {
    *TIER.get_or_init(detect)
}

/// The tier as the string the BENCH JSON records (`"simd"` field), so
/// artifacts from heterogeneous runners stay comparable.
pub fn tier_name() -> &'static str {
    match tier() {
        SimdTier::Scalar => "scalar",
        SimdTier::Avx2 => "avx2",
        SimdTier::Neon => "neon",
    }
}

/// Dot product, dispatched. Very short vectors skip straight to the
/// scalar path — below one vector tile the intrinsics only add call
/// overhead.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() < 16 {
        return scalar::dot(a, b);
    }
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier is only ever selected after runtime
        // detection of avx2+fma on this CPU.
        SimdTier::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon tier ⇒ runtime-detected NEON support.
        SimdTier::Neon => unsafe { neon::dot(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// `out[i·b_rows + j] = a_row_i · b_row_j` for row-major `a`
/// (`a_rows × k`) and `b` (`b_rows × k`) — the `A·Bᵀ` gemm both
/// operands row-major, dispatched. `out` must hold `a_rows · b_rows`.
pub fn matmul_nt_into(
    a: &[f32],
    a_rows: usize,
    k: usize,
    b: &[f32],
    b_rows: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), a_rows * k, "matmul_nt_into: lhs shape");
    assert_eq!(b.len(), b_rows * k, "matmul_nt_into: rhs shape");
    assert_eq!(out.len(), a_rows * b_rows, "matmul_nt_into: out shape");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 tier ⇒ runtime-detected avx2+fma.
        SimdTier::Avx2 => unsafe {
            avx2::matmul_nt_into(a, a_rows, k, b, b_rows, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon tier ⇒ runtime-detected NEON support.
        SimdTier::Neon => unsafe {
            neon::matmul_nt_into(a, a_rows, k, b, b_rows, out)
        },
        _ => scalar::matmul_nt_into(a, a_rows, k, b, b_rows, out),
    }
}

/// `y += alpha · x`, dispatched. Bit-exact across tiers (element-wise
/// mul+add, no reassociation, no FMA).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    if x.len() < 16 {
        return scalar::axpy(alpha, x, y);
    }
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 tier ⇒ runtime-detected avx2+fma.
        SimdTier::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon tier ⇒ runtime-detected NEON support.
        SimdTier::Neon => unsafe { neon::axpy(alpha, x, y) },
        _ => scalar::axpy(alpha, x, y),
    }
}

/// Hint the cache that `data`'s first line is about to be read (L1
/// temporal prefetch). On x86_64 this is `_mm_prefetch`; elsewhere a
/// volatile touch of the first element requests the line without
/// blocking retirement. No-op for empty slices.
#[inline]
pub fn prefetch_read(data: &[f32]) {
    if data.is_empty() {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    // SAFETY: the pointer is derived from a live slice; prefetch has no
    // memory effects beyond the cache.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(data.as_ptr() as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    // SAFETY: reading the first element of a live non-empty slice.
    unsafe {
        let _ = std::ptr::read_volatile(data.as_ptr());
    }
}

/// The portable reference kernels — always compiled on every arch, so
/// equivalence tests and the `perf_hotpath` SIMD-vs-scalar A/B cell can
/// pit them against the dispatched path inside one process.
pub mod scalar {
    /// Dot product with 4 accumulators (breaks the fp dependency chain;
    /// LLVM vectorizes this reasonably even without explicit
    /// intrinsics).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
        for i in 0..chunks {
            let j = i * 4;
            s0 += a[j] * b[j];
            s1 += a[j + 1] * b[j + 1];
            s2 += a[j + 2] * b[j + 2];
            s3 += a[j + 3] * b[j + 3];
        }
        let mut tail = 0.0f32;
        for j in chunks * 4..n {
            tail += a[j] * b[j];
        }
        (s0 + s1) + (s2 + s3) + tail
    }

    /// Scalar `A·Bᵀ`: j-blocked so a panel of `b` rows stays
    /// L2-resident while every `a` row streams past it.
    pub fn matmul_nt_into(
        a: &[f32],
        a_rows: usize,
        k: usize,
        b: &[f32],
        b_rows: usize,
        out: &mut [f32],
    ) {
        const BLOCK: usize = 64;
        let mut j0 = 0;
        while j0 < b_rows {
            let j1 = (j0 + BLOCK).min(b_rows);
            for i in 0..a_rows {
                let ar = &a[i * k..(i + 1) * k];
                let or = &mut out[i * b_rows..(i + 1) * b_rows];
                for j in j0..j1 {
                    or[j] = dot(ar, &b[j * k..(j + 1) * k]);
                }
            }
            j0 = j1;
        }
    }

    /// `y += alpha · x` (element-wise mul+add — the rounding reference
    /// the vector tiers reproduce exactly).
    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * xi;
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of one 8-lane register.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(_mm256_castps256_ps128(v), hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// 8-wide FMA dot with 4 independent accumulators (32 floats per
    /// main-loop iteration).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            c0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i)),
                _mm256_loadu_ps(bp.add(i)),
                c0,
            );
            c1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                c1,
            );
            c2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 16)),
                _mm256_loadu_ps(bp.add(i + 16)),
                c2,
            );
            c3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 24)),
                _mm256_loadu_ps(bp.add(i + 24)),
                c3,
            );
            i += 32;
        }
        while i + 8 <= n {
            c0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i)),
                _mm256_loadu_ps(bp.add(i)),
                c0,
            );
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(
            _mm256_add_ps(c0, c1),
            _mm256_add_ps(c2, c3),
        ));
        while i < n {
            sum += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        sum
    }

    /// One 4×2 register tile: 4 `a` rows against 2 `b` rows, 8 FMA
    /// accumulators living in registers across the whole `k` sweep (6
    /// loads feed 8 FMAs per 8-wide step).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tile_4x2(
        a: *const f32,
        k: usize,
        b0: *const f32,
        b1: *const f32,
        out: *mut f32,
        b_rows: usize,
    ) {
        let a0 = a;
        let a1 = a.add(k);
        let a2 = a.add(2 * k);
        let a3 = a.add(3 * k);
        let mut c00 = _mm256_setzero_ps();
        let mut c01 = _mm256_setzero_ps();
        let mut c10 = _mm256_setzero_ps();
        let mut c11 = _mm256_setzero_ps();
        let mut c20 = _mm256_setzero_ps();
        let mut c21 = _mm256_setzero_ps();
        let mut c30 = _mm256_setzero_ps();
        let mut c31 = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + 8 <= k {
            let vb0 = _mm256_loadu_ps(b0.add(p));
            let vb1 = _mm256_loadu_ps(b1.add(p));
            let va0 = _mm256_loadu_ps(a0.add(p));
            c00 = _mm256_fmadd_ps(va0, vb0, c00);
            c01 = _mm256_fmadd_ps(va0, vb1, c01);
            let va1 = _mm256_loadu_ps(a1.add(p));
            c10 = _mm256_fmadd_ps(va1, vb0, c10);
            c11 = _mm256_fmadd_ps(va1, vb1, c11);
            let va2 = _mm256_loadu_ps(a2.add(p));
            c20 = _mm256_fmadd_ps(va2, vb0, c20);
            c21 = _mm256_fmadd_ps(va2, vb1, c21);
            let va3 = _mm256_loadu_ps(a3.add(p));
            c30 = _mm256_fmadd_ps(va3, vb0, c30);
            c31 = _mm256_fmadd_ps(va3, vb1, c31);
            p += 8;
        }
        let mut s00 = hsum(c00);
        let mut s01 = hsum(c01);
        let mut s10 = hsum(c10);
        let mut s11 = hsum(c11);
        let mut s20 = hsum(c20);
        let mut s21 = hsum(c21);
        let mut s30 = hsum(c30);
        let mut s31 = hsum(c31);
        while p < k {
            let y0 = *b0.add(p);
            let y1 = *b1.add(p);
            let x0 = *a0.add(p);
            let x1 = *a1.add(p);
            let x2 = *a2.add(p);
            let x3 = *a3.add(p);
            s00 += x0 * y0;
            s01 += x0 * y1;
            s10 += x1 * y0;
            s11 += x1 * y1;
            s20 += x2 * y0;
            s21 += x2 * y1;
            s30 += x3 * y0;
            s31 += x3 * y1;
            p += 1;
        }
        *out = s00;
        *out.add(1) = s01;
        *out.add(b_rows) = s10;
        *out.add(b_rows + 1) = s11;
        *out.add(2 * b_rows) = s20;
        *out.add(2 * b_rows + 1) = s21;
        *out.add(3 * b_rows) = s30;
        *out.add(3 * b_rows + 1) = s31;
    }

    /// Register-blocked `A·Bᵀ`: 4×2 tiles inside the same 64-row `b`
    /// panel blocking as the scalar path; row/col remainders fall back
    /// to the vector dot.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_nt_into(
        a: &[f32],
        a_rows: usize,
        k: usize,
        b: &[f32],
        b_rows: usize,
        out: &mut [f32],
    ) {
        const MR: usize = 4;
        const NR: usize = 2;
        const BLOCK: usize = 64;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        let mut j0 = 0usize;
        while j0 < b_rows {
            let j1 = (j0 + BLOCK).min(b_rows);
            let mut i = 0usize;
            while i + MR <= a_rows {
                let mut j = j0;
                while j + NR <= j1 {
                    tile_4x2(
                        ap.add(i * k),
                        k,
                        bp.add(j * k),
                        bp.add((j + 1) * k),
                        op.add(i * b_rows + j),
                        b_rows,
                    );
                    j += NR;
                }
                while j < j1 {
                    let br = &b[j * k..(j + 1) * k];
                    for ii in i..i + MR {
                        out[ii * b_rows + j] =
                            dot(&a[ii * k..(ii + 1) * k], br);
                    }
                    j += 1;
                }
                i += MR;
            }
            while i < a_rows {
                let ar = &a[i * k..(i + 1) * k];
                for j in j0..j1 {
                    out[i * b_rows + j] = dot(ar, &b[j * k..(j + 1) * k]);
                }
                i += 1;
            }
            j0 = j1;
        }
    }

    /// Element-wise `y += alpha·x` — mul+add (NOT fmadd), so each lane
    /// rounds exactly like the scalar reference and the result is
    /// bit-identical across tiers.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(i)));
            let sum = _mm256_add_ps(_mm256_loadu_ps(yp.add(i)), prod);
            _mm256_storeu_ps(yp.add(i), sum);
            i += 8;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// 4-wide FMA dot with 4 independent accumulators (16 floats per
    /// main-loop iteration).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut c0 = vdupq_n_f32(0.0);
        let mut c1 = vdupq_n_f32(0.0);
        let mut c2 = vdupq_n_f32(0.0);
        let mut c3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 16 <= n {
            c0 = vfmaq_f32(c0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            c1 = vfmaq_f32(
                c1,
                vld1q_f32(ap.add(i + 4)),
                vld1q_f32(bp.add(i + 4)),
            );
            c2 = vfmaq_f32(
                c2,
                vld1q_f32(ap.add(i + 8)),
                vld1q_f32(bp.add(i + 8)),
            );
            c3 = vfmaq_f32(
                c3,
                vld1q_f32(ap.add(i + 12)),
                vld1q_f32(bp.add(i + 12)),
            );
            i += 16;
        }
        while i + 4 <= n {
            c0 = vfmaq_f32(c0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            i += 4;
        }
        let mut sum =
            vaddvq_f32(vaddq_f32(vaddq_f32(c0, c1), vaddq_f32(c2, c3)));
        while i < n {
            sum += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        sum
    }

    /// NEON `A·Bᵀ`: the scalar panel blocking with the vector dot per
    /// output cell (the 128-bit registers don't reward a wider tile the
    /// way AVX2's do).
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_nt_into(
        a: &[f32],
        a_rows: usize,
        k: usize,
        b: &[f32],
        b_rows: usize,
        out: &mut [f32],
    ) {
        const BLOCK: usize = 64;
        let mut j0 = 0usize;
        while j0 < b_rows {
            let j1 = (j0 + BLOCK).min(b_rows);
            for i in 0..a_rows {
                let ar = &a[i * k..(i + 1) * k];
                for j in j0..j1 {
                    out[i * b_rows + j] = dot(ar, &b[j * k..(j + 1) * k]);
                }
            }
            j0 = j1;
        }
    }

    /// Element-wise `y += alpha·x` — vmul+vadd (not vfma) to stay
    /// bit-identical to the scalar reference.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let prod = vmulq_f32(va, vld1q_f32(xp.add(i)));
            vst1q_f32(yp.add(i), vaddq_f32(vld1q_f32(yp.add(i)), prod));
            i += 4;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn pair(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seeded(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        (a, b)
    }

    #[test]
    fn tier_and_name_agree() {
        let t = tier();
        let n = tier_name();
        assert!(matches!(n, "scalar" | "avx2" | "neon"));
        assert_eq!(t, tier(), "tier must be stable across calls");
        match t {
            SimdTier::Scalar => assert_eq!(n, "scalar"),
            SimdTier::Avx2 => assert_eq!(n, "avx2"),
            SimdTier::Neon => assert_eq!(n, "neon"),
        }
    }

    #[test]
    fn force_scalar_parsing() {
        assert!(!force_scalar_requested(None));
        assert!(!force_scalar_requested(Some("")));
        assert!(!force_scalar_requested(Some("0")));
        assert!(force_scalar_requested(Some("1")));
        assert!(force_scalar_requested(Some("yes")));
    }

    #[test]
    fn dot_dispatch_matches_scalar_across_remainder_lengths() {
        // 0..=2·lanes and beyond: every tail-length class of both the
        // 32-wide main loop and the 8-wide secondary loop.
        for n in 0..=67 {
            let (a, b) = pair(n, 100 + n as u64);
            let want = scalar::dot(&a, &b);
            let got = dot(&a, &b);
            assert!(
                (got - want).abs() <= 1e-4 + want.abs() * 1e-4,
                "n={n}: dispatched {got} vs scalar {want}"
            );
        }
    }

    #[test]
    fn matmul_nt_dispatch_matches_scalar_on_awkward_shapes() {
        // Non-multiples of the 4×2 tile and of the 8-lane width, plus
        // shapes that straddle the 64-row panel boundary.
        for &(r, br, k) in &[
            (1usize, 1usize, 1usize),
            (2, 3, 4),
            (4, 2, 8),
            (5, 9, 13),
            (7, 70, 13),
            (8, 8, 32),
            (13, 66, 40),
            (3, 128, 9),
        ] {
            let mut rng = Rng::seeded(7000 + (r * 31 + br * 7 + k) as u64);
            let a: Vec<f32> =
                (0..r * k).map(|_| rng.gaussian_f32()).collect();
            let b: Vec<f32> =
                (0..br * k).map(|_| rng.gaussian_f32()).collect();
            let mut want = vec![0.0f32; r * br];
            let mut got = vec![0.0f32; r * br];
            scalar::matmul_nt_into(&a, r, k, &b, br, &mut want);
            matmul_nt_into(&a, r, k, &b, br, &mut got);
            for idx in 0..r * br {
                assert!(
                    (got[idx] - want[idx]).abs()
                        <= 1e-4 + want[idx].abs() * 1e-4,
                    "({r}x{k})·({br}x{k})ᵀ cell {idx}: {} vs {}",
                    got[idx],
                    want[idx]
                );
            }
        }
    }

    #[test]
    fn nan_propagates_through_dot_and_matmul() {
        // A NaN in the vector body and in the scalar tail both poison
        // the result, on every dispatch tier.
        for pos in [0usize, 17, 38] {
            let (mut a, b) = pair(39, 42);
            a[pos] = f32::NAN;
            assert!(dot(&a, &b).is_nan(), "NaN at {pos} must propagate");
            assert!(scalar::dot(&a, &b).is_nan());
        }
        let mut a = vec![1.0f32; 2 * 20];
        let b = vec![1.0f32; 3 * 20];
        a[20 + 5] = f32::NAN; // poisons row 1 only
        let mut out = vec![0.0f32; 2 * 3];
        matmul_nt_into(&a, 2, 20, &b, 3, &mut out);
        for j in 0..3 {
            assert!(!out[j].is_nan(), "row 0 must stay clean");
            assert!(out[3 + j].is_nan(), "row 1 col {j} must be NaN");
        }
    }

    #[test]
    fn inf_propagates_through_dot() {
        let mut a = vec![1.0f32; 40];
        let b = vec![2.0f32; 40];
        a[11] = f32::INFINITY;
        assert_eq!(dot(&a, &b), f32::INFINITY);
        a[12] = f32::NEG_INFINITY; // inf + (−inf) ⇒ NaN, like scalar
        assert!(dot(&a, &b).is_nan());
    }

    #[test]
    fn axpy_dispatch_is_bit_exact_vs_scalar() {
        for n in [0usize, 1, 7, 8, 15, 16, 33, 64, 129] {
            let (x, y0) = pair(n, 9000 + n as u64);
            let alpha = 0.37f32;
            let mut y_scalar = y0.clone();
            let mut y_simd = y0.clone();
            scalar::axpy(alpha, &x, &mut y_scalar);
            axpy(alpha, &x, &mut y_simd);
            for i in 0..n {
                assert_eq!(
                    y_scalar[i].to_bits(),
                    y_simd[i].to_bits(),
                    "n={n} elem {i}: axpy must be bit-exact across tiers"
                );
            }
        }
    }

    #[test]
    fn prefetch_is_harmless() {
        prefetch_read(&[]);
        let v = vec![1.0f32; 64];
        prefetch_read(&v);
        prefetch_read(&v[63..]);
    }
}
