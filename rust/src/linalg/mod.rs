//! Dense linear-algebra substrate (f32, row-major).
//!
//! No BLAS / ndarray offline, so the kernels this framework needs on the
//! Rust hot path — dot products, gemms against feature maps, row
//! normalization — are implemented here. The entry points (`dot`,
//! `axpy`, `Matrix::matmul_nt`) dispatch through [`simd`] — explicit
//! `std::arch` intrinsics (AVX2+FMA / NEON) chosen once at startup by
//! runtime feature detection, with the portable 4-accumulator scalar
//! loops always compiled in as the fallback and correctness reference.
//! [`quant`] adds the opt-in f16/i8 storage for the sampler's private
//! class-embedding copy. The heavy model math itself lives in the
//! AOT-compiled HLO (L1/L2); this module serves the *sampler* and
//! evaluation paths.

mod matrix;
pub mod quant;
pub mod simd;

pub use matrix::Matrix;
pub use quant::{ClassStore, QuantizeKind};

use crate::rng::Rng;

/// Dot product, SIMD-dispatched (AVX2/NEON when detected, 4-accumulator
/// scalar otherwise — see [`simd::tier`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

/// `y += alpha * x`, SIMD-dispatched. Bit-exact across dispatch tiers
/// (element-wise, no reassociation).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy(alpha, x, y)
}

/// Batched axpy over selected rows of a flat row-major table:
/// `y += Σ_k alphas[k] · table[ids[k]]` with rows of width `dim`.
///
/// This is the accumulation kernel behind weighted row-sums on the batch
/// path (e.g. the extreme-classification sparse-feature query assembly):
/// one pass per selected row, each a SIMD-dispatched [`axpy`]. Takes a
/// slice rather than a [`Matrix`] so embedding-table blocks qualify
/// without a copy.
pub fn axpy_rows(
    table: &[f32],
    dim: usize,
    ids: &[u32],
    alphas: &[f32],
    y: &mut [f32],
) {
    assert_eq!(ids.len(), alphas.len(), "axpy_rows: ids/alphas mismatch");
    assert_eq!(y.len(), dim, "axpy_rows: output dim mismatch");
    for (&id, &a) in ids.iter().zip(alphas.iter()) {
        let s = id as usize * dim;
        axpy(a, &table[s..s + dim], y);
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// L2-normalize in place; returns the original norm. Zero vectors are left
/// untouched (norm 0 returned) rather than producing NaNs.
pub fn l2_normalize(x: &mut [f32]) -> f32 {
    let n = norm2(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

/// Cosine similarity; 0 if either vector is zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm2(a);
    let nb = norm2(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// Random unit vector of dimension `d` (gaussian direction, normalized).
pub fn unit_vector(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    rng.fill_gaussian_f32(&mut v);
    l2_normalize(&mut v);
    v
}

/// Numerically-stable log-sum-exp of a slice (f64 accumulation).
pub fn logsumexp(xs: &[f64]) -> f64 {
    let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if mx.is_infinite() {
        return mx;
    }
    let s: f64 = xs.iter().map(|&x| (x - mx).exp()).sum();
    mx + s.ln()
}

/// Stable softmax of a slice (f64), returning a normalized pmf.
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|&x| (x - mx).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::seeded(21);
        for n in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3 + naive.abs() * 1e-4);
        }
    }

    #[test]
    fn normalize_gives_unit_norm() {
        let mut rng = Rng::seeded(22);
        let mut v: Vec<f32> = (0..37).map(|_| rng.gaussian_f32() * 5.0).collect();
        let n0 = l2_normalize(&mut v);
        assert!(n0 > 0.0);
        assert!((norm2(&v) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn normalize_zero_is_noop() {
        let mut v = vec![0.0f32; 8];
        assert_eq!(l2_normalize(&mut v), 0.0);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn unit_vector_is_unit() {
        let mut rng = Rng::seeded(23);
        let v = unit_vector(&mut rng, 100);
        assert!((norm2(&v) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn logsumexp_stable_and_correct() {
        // Large offsets must not overflow.
        let v = [1000.0, 1000.0];
        assert!((logsumexp(&v) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        let w = [0.0, (2f64).ln(), (3f64).ln()];
        assert!((logsumexp(&w) - (6f64).ln()).abs() < 1e-12);
        assert_eq!(logsumexp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn axpy_rows_matches_manual_accumulation() {
        // 3×2 row-major table.
        let table = vec![1.0f32, 2., 3., 4., 5., 6.];
        let mut y = vec![10.0f32, 20.0];
        axpy_rows(&table, 2, &[2, 0, 2], &[1.0, 0.5, -1.0], &mut y);
        // 10 + 5 + 0.5 − 5 = 10.5; 20 + 6 + 1 − 6 = 21.
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn cosine_of_parallel_and_orthogonal() {
        assert!((cosine(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 3.0]).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}
