//! Row-major f32 matrix with the handful of operations the framework needs
//! outside the AOT-compiled HLO: row access for embedding tables, matvec
//! for feature maps, Gram–Schmidt for orthogonal random features.

use super::{dot, l2_normalize};
use crate::rng::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From an existing row-major buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Matrix::from_vec: size mismatch");
        Self { rows, cols, data }
    }

    /// i.i.d. standard gaussian entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_gaussian_f32(&mut m.data);
        m
    }

    /// Gaussian entries scaled by `std`.
    pub fn randn_scaled(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Self {
        let mut m = Self::randn(rng, rows, cols);
        for v in m.data.iter_mut() {
            *v *= std;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// `out[i] = row_i · x` for all rows. `out.len() == rows`.
    pub fn matvec_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot(self.row(i), x);
        }
    }

    /// Convenience allocating matvec.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// Dense matmul `self (r×c) @ other (c×k)` with a column-major-ish
    /// right operand. Scalar on purpose: every hot gemm in the crate
    /// goes through [`Matrix::matmul_nt`] (both operands row-major,
    /// SIMD-dispatched), and this variant survives as the independent
    /// reference implementation the `matmul_nt` tests check against.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: inner dims");
        let (r, c, k) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(r, k);
        for i in 0..r {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * k..(i + 1) * k];
            for (l, &a) in a_row.iter().enumerate().take(c) {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(l);
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Blocked gemm against a transposed right operand:
    /// `self (r×d) @ otherᵀ` where `other` is `k×d`, giving `out (r×k)`
    /// with `out[i][j] = self.row(i) · other.row(j)`.
    ///
    /// Both operands stream row-major (no transposed strides) and the
    /// whole product runs through the runtime-dispatched microkernel in
    /// [`super::simd`] — a register-blocked 4×2 FMA tile on AVX2, a
    /// NEON vector dot on aarch64, and the blocked 4-accumulator scalar
    /// loop everywhere else. This is the batch-path workhorse: feature
    /// maps compute `Φ = f(U · Wᵀ)` for a whole batch `U` in one call
    /// instead of `r` matvecs.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt: inner dims");
        let (r, k) = (self.rows, other.rows);
        let mut out = Matrix::zeros(r, k);
        super::simd::matmul_nt_into(
            &self.data,
            r,
            self.cols,
            &other.data,
            k,
            &mut out.data,
        );
        out
    }

    /// Append one row (the dynamic-vocabulary growth path: kernel
    /// samplers extend their class-embedding copy in place instead of
    /// reallocating the whole table per insert; `Vec` doubling makes the
    /// copy cost amortized O(cols) per appended row).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "push_row: width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Transposed copy, tiled so both the row-major reads and the
    /// column-major writes stay within one cache-block worth of lines
    /// at a time (the naive double loop streams reads but scatters a
    /// write per row across `rows` distinct lines).
    pub fn transpose(&self) -> Matrix {
        const TILE: usize = 32;
        let mut out = Matrix::zeros(self.cols, self.rows);
        let mut i0 = 0usize;
        while i0 < self.rows {
            let i1 = (i0 + TILE).min(self.rows);
            let mut j0 = 0usize;
            while j0 < self.cols {
                let j1 = (j0 + TILE).min(self.cols);
                for i in i0..i1 {
                    for j in j0..j1 {
                        out.data[j * self.rows + i] =
                            self.data[i * self.cols + j];
                    }
                }
                j0 = j1;
            }
            i0 = i1;
        }
        out
    }

    /// Return a copy whose rows are L2-normalized (zero rows untouched).
    pub fn l2_normalized_rows(mut self) -> Matrix {
        for i in 0..self.rows {
            l2_normalize(self.row_mut(i));
        }
        self
    }

    /// In-place row normalization.
    pub fn normalize_rows_in_place(&mut self) {
        for i in 0..self.rows {
            l2_normalize(self.row_mut(i));
        }
    }

    /// Orthonormalize the rows in place by modified Gram–Schmidt
    /// (requires rows <= cols). Rows that collapse numerically are
    /// re-randomized from `rng` and re-orthogonalized.
    pub fn orthonormalize_rows(&mut self, rng: &mut Rng) {
        assert!(
            self.rows <= self.cols,
            "orthonormalize_rows: rows {} > cols {}",
            self.rows,
            self.cols
        );
        for i in 0..self.rows {
            loop {
                // Subtract projections on previous rows.
                for j in 0..i {
                    let proj = dot(self.row(i), self.row(j));
                    let (head, tail) = self.data.split_at_mut(i * self.cols);
                    let prev = &head[j * self.cols..(j + 1) * self.cols];
                    let cur = &mut tail[..self.cols];
                    for (c, p) in cur.iter_mut().zip(prev.iter()) {
                        *c -= proj * p;
                    }
                }
                let n = l2_normalize(self.row_mut(i));
                if n > 1e-6 {
                    break;
                }
                // Degenerate row — resample and retry.
                let row = self.row_mut(i);
                rng.fill_gaussian_f32(row);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access_layout() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let y = m.matvec(&[1., 0., -1.]);
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seeded(31);
        let a = Matrix::randn(&mut rng, 4, 4);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let b = a.matmul(&eye);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_matches_matmul_of_transpose() {
        let mut rng = Rng::seeded(35);
        // Odd sizes cross the column-block boundary logic.
        let a = Matrix::randn(&mut rng, 7, 13);
        let b = Matrix::randn(&mut rng, 70, 13);
        let fast = a.matmul_nt(&b);
        let slow = a.matmul(&b.transpose());
        assert_eq!(fast.rows(), 7);
        assert_eq!(fast.cols(), 70);
        for (x, y) in fast.data().iter().zip(slow.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::seeded(32);
        let a = Matrix::randn(&mut rng, 3, 5);
        let b = a.transpose().transpose();
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_is_exact_across_tile_boundaries() {
        // 33×70 straddles the 32-wide tiles in both dimensions.
        let mut rng = Rng::seeded(36);
        let a = Matrix::randn(&mut rng, 33, 70);
        let t = a.transpose();
        assert_eq!(t.rows(), 70);
        assert_eq!(t.cols(), 33);
        for i in 0..33 {
            for j in 0..70 {
                assert_eq!(a.get(i, j).to_bits(), t.get(j, i).to_bits());
            }
        }
        assert_eq!(a, t.transpose());
    }

    #[test]
    fn normalized_rows_are_unit() {
        let mut rng = Rng::seeded(33);
        let m = Matrix::randn(&mut rng, 10, 7).l2_normalized_rows();
        for i in 0..10 {
            let n = super::super::norm2(m.row(i));
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::seeded(34);
        let mut m = Matrix::randn(&mut rng, 6, 8);
        m.orthonormalize_rows(&mut rng);
        for i in 0..6 {
            for j in 0..6 {
                let d = dot(m.row(i), m.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j}): {d}");
            }
        }
    }
}
