//! Command-line parsing substrate (no clap offline).
//!
//! A deliberately small, typed flag parser supporting:
//!
//! * subcommands (`rfsoftmax train --config cfg.json --sampler rff`),
//! * `--flag value` and `--flag=value` forms,
//! * typed accessors with defaults and range validation,
//! * automatic `--help` text generation,
//! * collection of unknown flags into errors (catches typos early).

use std::collections::BTreeMap;
use std::fmt;

/// A declared flag for help text + validation.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed argument bag for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    bools: Vec<String>,
}

/// CLI error with a user-facing message.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (already excluding the program name / subcommand).
    /// `bool_flags` lists flags that take no value (e.g. `--verbose`).
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    let (k, v) = body.split_at(eq);
                    out.flags.insert(k.to_string(), v[1..].to_string());
                } else if bool_flags.contains(&body) {
                    out.bools.push(body.to_string());
                } else {
                    let v = raw.get(i + 1).ok_or_else(|| {
                        CliError(format!("flag --{body} expects a value"))
                    })?;
                    out.flags.insert(body.to_string(), v.clone());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{name}: expected integer, got '{v}'"))
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{name}: expected integer, got '{v}'"))
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{name}: expected float, got '{v}'"))
            }),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32, CliError> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    /// Reject flags that are not in the allowed set (typo protection).
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), CliError> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(CliError(format!(
                    "unknown flag --{k}; known flags: {}",
                    allowed
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )));
            }
        }
        Ok(())
    }

    /// All `--key value` overrides as (key, value) pairs, for config overlay.
    pub fn overrides(&self) -> impl Iterator<Item = (&str, &str)> {
        self.flags.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

/// Render a help block for a subcommand.
pub fn render_help(command: &str, about: &str, flags: &[FlagSpec]) -> String {
    let mut s = format!("{command} — {about}\n\nFlags:\n");
    for f in flags {
        let default = f
            .default
            .as_ref()
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_both_flag_forms() {
        let a = Args::parse(&raw(&["--x", "1", "--y=2", "pos"]), &[]).unwrap();
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.get("y"), Some("2"));
        assert_eq!(a.positional(), &["pos".to_string()]);
    }

    #[test]
    fn bool_flags_take_no_value() {
        let a = Args::parse(&raw(&["--verbose", "--n", "3"]), &["verbose"]).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = Args::parse(&raw(&["--lr", "0.5"]), &[]).unwrap();
        assert_eq!(a.f64_or("lr", 1.0).unwrap(), 0.5);
        assert_eq!(a.f64_or("missing", 1.0).unwrap(), 1.0);
        assert!(a.f64_or("lr", 1.0).is_ok());
        let bad = Args::parse(&raw(&["--lr", "abc"]), &[]).unwrap();
        assert!(bad.f64_or("lr", 1.0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["--x"]), &[]).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(&raw(&["--tpyo", "1"]), &[]).unwrap();
        assert!(a.check_known(&["typo"]).is_err());
        assert!(a.check_known(&["tpyo"]).is_ok());
    }

    #[test]
    fn help_rendering() {
        let h = render_help(
            "train",
            "train a model",
            &[FlagSpec { name: "steps", help: "number of steps", default: Some("100".into()) }],
        );
        assert!(h.contains("--steps"));
        assert!(h.contains("default: 100"));
    }
}
