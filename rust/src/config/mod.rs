//! Typed configuration system.
//!
//! Configs are plain structs with JSON (de)serialization through the
//! [`crate::json`] substrate plus a `--section.key=value` command-line
//! overlay, so every experiment is reproducible from a single file and
//! every bench/example can tweak parameters without recompiling:
//!
//! ```text
//! rfsoftmax train --config runs/ptb.json --sampler.kind rff --sampler.dim 1024
//! ```

use crate::json::{self, Json};
use std::fmt;

/// Which model family to instantiate (see `python/compile/model.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Language model: embedding → LSTM → L2-normalized h (paper §4.1 NLP).
    Lm,
    /// Extreme classification: sparse features → projection → normalized h.
    Extreme,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "lm" => Ok(ModelKind::Lm),
            "extreme" => Ok(ModelKind::Extreme),
            _ => Err(ConfigError(format!("unknown model kind '{s}' (lm|extreme)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Lm => "lm",
            ModelKind::Extreme => "extreme",
        }
    }
}

/// Which negative-sampling distribution the coordinator uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// RF-softmax (the paper's method): q_i ∝ φ(c_i)ᵀφ(h), RFF map.
    Rff,
    /// Quadratic kernel sampling (Blanc & Rendle 2018 baseline).
    Quadratic,
    /// Uniform over negatives.
    Uniform,
    /// Log-uniform (Zipfian id-rank prior; the classic TF sampler).
    LogUniform,
    /// Static unigram prior via alias table.
    Unigram,
    /// Exact softmax distribution (EXP baseline, O(dn)).
    Exact,
    /// Gumbel-top-k over exact logits (extension baseline, paper §1.1 [13]).
    Gumbel,
    /// No sampling — full softmax loss (FULL baseline).
    Full,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "rff" => Ok(SamplerKind::Rff),
            "quadratic" => Ok(SamplerKind::Quadratic),
            "uniform" => Ok(SamplerKind::Uniform),
            "loguniform" => Ok(SamplerKind::LogUniform),
            "unigram" => Ok(SamplerKind::Unigram),
            "exact" | "exp" => Ok(SamplerKind::Exact),
            "gumbel" => Ok(SamplerKind::Gumbel),
            "full" => Ok(SamplerKind::Full),
            _ => Err(ConfigError(format!(
                "unknown sampler '{s}' (rff|quadratic|uniform|loguniform|unigram|exact|gumbel|full)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Rff => "rff",
            SamplerKind::Quadratic => "quadratic",
            SamplerKind::Uniform => "uniform",
            SamplerKind::LogUniform => "loguniform",
            SamplerKind::Unigram => "unigram",
            SamplerKind::Exact => "exact",
            SamplerKind::Gumbel => "gumbel",
            SamplerKind::Full => "full",
        }
    }
}

/// Feature-map family for kernel-based samplers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureMapKind {
    /// Classic Random Fourier Features (paper eq. 17).
    Rff,
    /// Orthogonal Random Features (Yu et al. 2016).
    Orf,
    /// Structured Orthogonal Random Features (HD₁HD₂HD₃, O(D log d)).
    Sorf,
}

impl FeatureMapKind {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "rff" => Ok(FeatureMapKind::Rff),
            "orf" => Ok(FeatureMapKind::Orf),
            "sorf" => Ok(FeatureMapKind::Sorf),
            _ => Err(ConfigError(format!("unknown feature map '{s}' (rff|orf|sorf)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FeatureMapKind::Rff => "rff",
            FeatureMapKind::Orf => "orf",
            FeatureMapKind::Sorf => "sorf",
        }
    }
}

/// Model hyperparameters.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub kind: ModelKind,
    /// Number of classes n (vocab size for LM).
    pub num_classes: usize,
    /// Embedding dimension d.
    pub embed_dim: usize,
    /// LSTM hidden size (LM only).
    pub hidden_dim: usize,
    /// Unrolled sequence length (LM only).
    pub seq_len: usize,
    /// Sparse input feature dimension v (extreme only).
    pub feature_dim: usize,
    /// Non-zeros per sparse input (extreme only).
    pub nnz: usize,
    /// Softmax inverse temperature τ (paper eq. 1). Temperature = 1/√τ.
    pub tau: f32,
    /// L2-normalize input & class embeddings (paper §3.2 requirement).
    pub normalize: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            kind: ModelKind::Lm,
            num_classes: 10_000,
            embed_dim: 200,
            hidden_dim: 256,
            seq_len: 20,
            feature_dim: 4096,
            nnz: 32,
            // Paper §4.1: temperature 1/√τ = 0.3 ⇒ τ ≈ 11.1.
            tau: 1.0 / (0.3f32 * 0.3f32),
            normalize: true,
        }
    }
}

/// Sampler hyperparameters.
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    pub kind: SamplerKind,
    /// Number of sampled negatives m per example.
    pub num_negatives: usize,
    /// Feature dimension D of the kernel map (RFF/quadratic).
    pub dim: usize,
    /// RFF Gaussian kernel parameter ν (paper eq. 16). The paper's best
    /// setting is T = 1/√ν = 0.5 ⇒ ν = 4.
    pub nu: f32,
    /// Feature-map family for RFF sampling.
    pub feature_map: FeatureMapKind,
    /// Quadratic kernel α (paper eq. 15; [12] uses 100).
    pub alpha: f32,
    /// Train the Quadratic baseline with the absolute-softmax loss
    /// (paper §4.1 / [12]). Off by default: under our synthetic corpora
    /// and the standard perplexity eval, the |o| objective admits
    /// negative-logit degenerate solutions and diverges — see
    /// EXPERIMENTS.md (documented deviation).
    pub absolute: bool,
    /// Share one negative set across the batch (standard trick; the paper's
    /// timing setup samples per batch).
    pub share_across_batch: bool,
    /// Shard count for the kernel sampling tree (rounded up to a power of
    /// two). `0` or `1` keeps the single monolithic tree; `> 1` uses the
    /// two-level [`crate::sampler::ShardedKernelTree`], whose disjoint
    /// shards absorb batched embedding updates in parallel. Applies to
    /// the kernel samplers (`rff`, `quadratic` — except when the
    /// quadratic memory fallback routes to the bucket sampler); static
    /// samplers have no tree and ignore it.
    pub shards: usize,
    /// Planned ceiling on runtime class growth (`add_classes` /
    /// `extend_vocab`). `0` = no growth planned. Only sizing decisions
    /// read it — the quadratic memory fallback gates on the capacity the
    /// tree would occupy after growing to this many classes (capacity
    /// doubling means a grown tree is as large as one built at this size
    /// up front), so the fallback choice cannot be invalidated later by
    /// churn. Growth beyond the ceiling still works; it just wasn't
    /// budgeted for.
    pub max_capacity: usize,
    /// Live-count imbalance ratio (heaviest/lightest shard) above which
    /// a sharded kernel tree redistributes its live classes after a
    /// mutation. Retire-skew is the only way shards drift (inserts
    /// already route to the lightest shard). `<= 1` disables. Only
    /// meaningful with `sampler.shards > 1`.
    pub rebalance: f64,
    /// Storage precision of the kernel samplers' private class-embedding
    /// copy (`none` = f32, `f16`, `i8` with per-row scales). Halves or
    /// quarters that copy's memory; the sampled distribution drifts only
    /// within the RFF bias budget (see the chi-square drift test in
    /// `rust/tests/integration_sampler_stats.rs`). φ is always computed
    /// from the dequantized stored rows, so tree bookkeeping stays
    /// exactly consistent within a run.
    pub quantize: crate::linalg::QuantizeKind,
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self {
            kind: SamplerKind::Rff,
            num_negatives: 100,
            dim: 1024,
            nu: 4.0,
            feature_map: FeatureMapKind::Rff,
            alpha: 100.0,
            absolute: false,
            share_across_batch: true,
            shards: 0,
            max_capacity: 0,
            rebalance: 4.0,
            quantize: crate::linalg::QuantizeKind::None,
            seed: 17,
        }
    }
}

/// Online-serving subsystem parameters (`rust/src/serving`).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Double-buffered async class updates in the trainers: stage
    /// `update_classes` into a shadow sampler on a writer thread
    /// (overlapping the step's loss execution) and swap the snapshot at
    /// the next step boundary — the ROADMAP "async double-buffered tree
    /// updates" item. Always *distribution*-identical to synchronous
    /// mode, and draw-*stream*-identical when the sampler's `fork` is an
    /// exact clone (sharded kernel trees, static samplers); the
    /// unsharded kernel samplers route onto a 1-shard sharded tree under
    /// this flag, so their served streams are exact too.
    ///
    /// **On by default** (flipped in PR 3 per the ROADMAP, gated on the
    /// stream-exact direct-vs-double-buffered equivalence tests in
    /// `rust/tests/integration_serving.rs`): the tree refresh overlaps
    /// the step at no distributional cost. Set
    /// `--serving.double_buffer false` to keep the single-threaded
    /// synchronous reference path. Samplers without a serving fork (the
    /// quadratic bucket memory fallback) degrade to synchronous updates
    /// with a one-line stderr warning instead of failing, so the default
    /// stays trainable at every size.
    pub double_buffer: bool,
    /// Micro-batcher: max requests coalesced into one serving batch.
    pub max_batch: usize,
    /// Micro-batcher: max extra wait for a batch to fill, in
    /// microseconds. `0` (the default) serves whatever has queued as
    /// soon as the batcher is free — coalescing still emerges under load
    /// because requests accumulate while a batch is being served —
    /// without taxing every light-load request with an artificial delay.
    pub max_wait_us: u64,
    /// TCP bind address for the L4 transport (`serve-bench --transport
    /// tcp`, `TransportServer::bind_tcp`): `host:port`, where port `0`
    /// asks the kernel for an ephemeral port (the server reports the
    /// real one via `endpoint()`). The default binds loopback only —
    /// serving cross-machine means deliberately widening this to an
    /// interface address.
    pub listen: String,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            double_buffer: true,
            max_batch: 32,
            max_wait_us: 0,
            listen: "127.0.0.1:0".into(),
        }
    }
}

/// Replicated-serving cluster parameters (`rust/src/cluster`).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Static replica roster: comma-separated endpoints
    /// (`tcp:HOST:PORT` / `uds:PATH`), empty = single-process serving.
    /// The registry's consistent-hash ring assigns every class id to
    /// exactly one of these.
    pub replicas: String,
    /// Per-replica connect/read deadline in milliseconds — a dead
    /// replica fails with a typed `Timeout` instead of hanging the
    /// router; the failover path depends on it.
    pub request_timeout_ms: u64,
    /// Hedge straggler sub-requests: after a p99-derived delay, resend
    /// the sub-request on a fresh connection and take the first answer.
    pub hedge: bool,
    /// Virtual nodes per replica on the consistent-hash ring (more =
    /// smoother class balance, marginally slower ring lookups).
    pub virtual_nodes: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            replicas: String::new(),
            request_timeout_ms: 1000,
            hedge: false,
            virtual_nodes: 64,
        }
    }
}

/// Which execution backend runs the training step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TrainBackend {
    /// Fused one-pass f32 kernels over `linalg::simd` (the default):
    /// no artifacts directory, no HostTensor round-trips, scratch
    /// buffers reused across steps (see `runtime::native`).
    #[default]
    Native,
    /// The PJRT/HLO runtime (`make artifacts` + the `pjrt` cargo
    /// feature). Requesting it from a binary built without the feature
    /// is a runtime error with a rebuild hint.
    Pjrt,
}

impl TrainBackend {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "native" => Ok(TrainBackend::Native),
            "pjrt" => Ok(TrainBackend::Pjrt),
            _ => Err(ConfigError(format!(
                "unknown train backend '{s}' (native|pjrt)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainBackend::Native => "native",
            TrainBackend::Pjrt => "pjrt",
        }
    }
}

/// Optimizer selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Momentum,
    Adagrad,
    Adam,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "momentum" => Ok(OptimizerKind::Momentum),
            "adagrad" => Ok(OptimizerKind::Adagrad),
            "adam" => Ok(OptimizerKind::Adam),
            _ => Err(ConfigError(format!(
                "unknown optimizer '{s}' (sgd|momentum|adagrad|adam)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Momentum => "momentum",
            OptimizerKind::Adagrad => "adagrad",
            OptimizerKind::Adam => "adam",
        }
    }
}

/// Training-loop parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Step-execution backend: `native` (fused in-process kernels, the
    /// default — needs no artifacts) or `pjrt` (HLO artifacts via the
    /// optional `pjrt` cargo feature).
    pub backend: TrainBackend,
    pub batch_size: usize,
    pub steps: usize,
    pub lr: f32,
    pub optimizer: OptimizerKind,
    /// Per-coordinate gradient clip (Theorem 1's bounded-gradient M).
    pub grad_clip: f32,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Sampling worker threads in the coordinator.
    pub workers: usize,
    /// Prefetch depth of the batch pipeline (double buffering = 2).
    pub pipeline_depth: usize,
    pub seed: u64,
    /// Optional checkpoint directory.
    pub checkpoint_dir: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            backend: TrainBackend::Native,
            batch_size: 32,
            steps: 500,
            lr: 0.1,
            optimizer: OptimizerKind::Adagrad,
            grad_clip: 10.0,
            eval_every: 100,
            eval_batches: 8,
            workers: 2,
            pipeline_depth: 2,
            seed: 42,
            checkpoint_dir: None,
        }
    }
}

/// Synthetic-dataset parameters (see DESIGN.md §2 substitutions).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// "synthlm" | "extreme".
    pub dataset: String,
    /// Zipf exponent of the unigram class prior.
    pub zipf_s: f64,
    /// Rank of the low-rank Markov transition structure (synthlm).
    pub markov_rank: usize,
    /// Interpolation weight of Markov structure vs unigram prior.
    pub markov_weight: f64,
    /// Training tokens (synthlm) or examples (extreme).
    pub train_size: usize,
    /// Validation tokens/examples.
    pub valid_size: usize,
    /// Labels per example (extreme, multi-label → multi-class reduction).
    pub labels_per_example: usize,
    /// Latent dimension d* of the planted extreme-classification model.
    /// Lower values concentrate the label distribution (more examples per
    /// class), which is what makes PREC@k learnable at our reduced
    /// train-set sizes (paper datasets have 10⁵–10⁶ training points).
    pub latent_dim: usize,
    /// Topic clusters of the planted generator (see
    /// [`crate::data::extreme::ExtremeParams::clusters`]).
    pub clusters: usize,
    /// Noise std of the planted-embedding generator (extreme).
    pub noise: f64,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            dataset: "synthlm".to_string(),
            zipf_s: 1.0,
            markov_rank: 16,
            markov_weight: 0.7,
            train_size: 200_000,
            valid_size: 20_000,
            labels_per_example: 3,
            latent_dim: 12,
            clusters: 200,
            noise: 0.3,
            seed: 7,
        }
    }
}

/// The top-level experiment config.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub model: ModelConfig,
    pub sampler: SamplerConfig,
    pub serving: ServingConfig,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
    pub data: DataConfig,
}

/// Config error with a user-facing message.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Load from a JSON file, then apply `--section.key=value` overrides.
    pub fn load(
        path: Option<&str>,
        overrides: impl Iterator<Item = (String, String)>,
    ) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| ConfigError(format!("cannot read {p}: {e}")))?;
            let j = json::parse(&text)
                .map_err(|e| ConfigError(format!("{p}: {e}")))?;
            cfg.apply_json(&j)?;
        }
        for (k, v) in overrides {
            cfg.set(&k, &v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply a parsed JSON document (sections: model/sampler/train/data).
    pub fn apply_json(&mut self, j: &Json) -> Result<(), ConfigError> {
        let obj = j
            .as_object()
            .ok_or_else(|| ConfigError("top level must be an object".into()))?;
        for (section, body) in obj {
            let fields = body.as_object().ok_or_else(|| {
                ConfigError(format!("section '{section}' must be an object"))
            })?;
            for (key, val) in fields {
                let flat = format!("{section}.{key}");
                let as_text = match val {
                    Json::Str(s) => s.clone(),
                    Json::Num(n) => n.to_string(),
                    Json::Bool(b) => b.to_string(),
                    _ => {
                        return Err(ConfigError(format!(
                            "{flat}: unsupported value type"
                        )))
                    }
                };
                self.set(&flat, &as_text)?;
            }
        }
        Ok(())
    }

    /// Set one `section.key` from its string form.
    pub fn set(&mut self, key: &str, v: &str) -> Result<(), ConfigError> {
        fn us(key: &str, v: &str) -> Result<usize, ConfigError> {
            v.parse()
                .map_err(|_| ConfigError(format!("{key}: expected integer, got '{v}'")))
        }
        fn f32v(key: &str, v: &str) -> Result<f32, ConfigError> {
            v.parse()
                .map_err(|_| ConfigError(format!("{key}: expected float, got '{v}'")))
        }
        fn f64v(key: &str, v: &str) -> Result<f64, ConfigError> {
            v.parse()
                .map_err(|_| ConfigError(format!("{key}: expected float, got '{v}'")))
        }
        fn u64v(key: &str, v: &str) -> Result<u64, ConfigError> {
            v.parse()
                .map_err(|_| ConfigError(format!("{key}: expected integer, got '{v}'")))
        }
        fn boolean(key: &str, v: &str) -> Result<bool, ConfigError> {
            match v {
                "true" | "1" => Ok(true),
                "false" | "0" => Ok(false),
                _ => Err(ConfigError(format!("{key}: expected bool, got '{v}'"))),
            }
        }

        match key {
            "model.kind" => self.model.kind = ModelKind::parse(v)?,
            "model.num_classes" => self.model.num_classes = us(key, v)?,
            "model.embed_dim" => self.model.embed_dim = us(key, v)?,
            "model.hidden_dim" => self.model.hidden_dim = us(key, v)?,
            "model.seq_len" => self.model.seq_len = us(key, v)?,
            "model.feature_dim" => self.model.feature_dim = us(key, v)?,
            "model.nnz" => self.model.nnz = us(key, v)?,
            "model.tau" => self.model.tau = f32v(key, v)?,
            "model.temperature" => {
                let t = f32v(key, v)?;
                if t <= 0.0 {
                    return Err(ConfigError("temperature must be > 0".into()));
                }
                self.model.tau = 1.0 / (t * t);
            }
            "model.normalize" => self.model.normalize = boolean(key, v)?,

            "sampler.kind" => self.sampler.kind = SamplerKind::parse(v)?,
            "sampler.num_negatives" | "sampler.m" => {
                self.sampler.num_negatives = us(key, v)?
            }
            "sampler.dim" | "sampler.D" => self.sampler.dim = us(key, v)?,
            "sampler.nu" => self.sampler.nu = f32v(key, v)?,
            "sampler.T" => {
                let t = f32v(key, v)?;
                if t <= 0.0 {
                    return Err(ConfigError("sampler.T must be > 0".into()));
                }
                self.sampler.nu = 1.0 / (t * t);
            }
            "sampler.feature_map" => {
                self.sampler.feature_map = FeatureMapKind::parse(v)?
            }
            "sampler.alpha" => self.sampler.alpha = f32v(key, v)?,
            "sampler.absolute" => self.sampler.absolute = boolean(key, v)?,
            "sampler.share_across_batch" => {
                self.sampler.share_across_batch = boolean(key, v)?
            }
            "sampler.shards" => self.sampler.shards = us(key, v)?,
            "sampler.max_capacity" => {
                self.sampler.max_capacity = us(key, v)?
            }
            "sampler.rebalance" => self.sampler.rebalance = f64v(key, v)?,
            "sampler.quantize" => {
                self.sampler.quantize =
                    crate::linalg::QuantizeKind::parse(v).ok_or_else(|| {
                        ConfigError(format!(
                            "unknown quantize mode '{v}' (none|f16|i8)"
                        ))
                    })?
            }
            "sampler.seed" => self.sampler.seed = u64v(key, v)?,

            "serving.double_buffer" => {
                self.serving.double_buffer = boolean(key, v)?
            }
            "serving.max_batch" => self.serving.max_batch = us(key, v)?,
            "serving.max_wait_us" => self.serving.max_wait_us = u64v(key, v)?,
            "serving.listen" => self.serving.listen = v.to_string(),

            "cluster.replicas" => self.cluster.replicas = v.to_string(),
            "cluster.request_timeout_ms" => {
                self.cluster.request_timeout_ms = u64v(key, v)?
            }
            "cluster.hedge" => self.cluster.hedge = boolean(key, v)?,
            "cluster.virtual_nodes" => {
                self.cluster.virtual_nodes = us(key, v)?
            }

            "train.backend" => self.train.backend = TrainBackend::parse(v)?,
            "train.batch_size" => self.train.batch_size = us(key, v)?,
            "train.steps" => self.train.steps = us(key, v)?,
            "train.lr" => self.train.lr = f32v(key, v)?,
            "train.optimizer" => self.train.optimizer = OptimizerKind::parse(v)?,
            "train.grad_clip" => self.train.grad_clip = f32v(key, v)?,
            "train.eval_every" => self.train.eval_every = us(key, v)?,
            "train.eval_batches" => self.train.eval_batches = us(key, v)?,
            "train.workers" => self.train.workers = us(key, v)?,
            "train.pipeline_depth" => self.train.pipeline_depth = us(key, v)?,
            "train.seed" => self.train.seed = u64v(key, v)?,
            "train.checkpoint_dir" => {
                self.train.checkpoint_dir = Some(v.to_string())
            }

            "data.dataset" => self.data.dataset = v.to_string(),
            "data.zipf_s" => self.data.zipf_s = f64v(key, v)?,
            "data.markov_rank" => self.data.markov_rank = us(key, v)?,
            "data.markov_weight" => self.data.markov_weight = f64v(key, v)?,
            "data.train_size" => self.data.train_size = us(key, v)?,
            "data.valid_size" => self.data.valid_size = us(key, v)?,
            "data.labels_per_example" => {
                self.data.labels_per_example = us(key, v)?
            }
            "data.latent_dim" => self.data.latent_dim = us(key, v)?,
            "data.clusters" => self.data.clusters = us(key, v)?,
            "data.noise" => self.data.noise = f64v(key, v)?,
            "data.seed" => self.data.seed = u64v(key, v)?,

            _ => return Err(ConfigError(format!("unknown config key '{key}'"))),
        }
        Ok(())
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.model.num_classes < 2 {
            return Err(ConfigError("model.num_classes must be >= 2".into()));
        }
        if self.model.embed_dim == 0 {
            return Err(ConfigError("model.embed_dim must be > 0".into()));
        }
        if self.model.tau <= 0.0 {
            return Err(ConfigError("model.tau must be > 0".into()));
        }
        if self.sampler.kind != SamplerKind::Full
            && self.sampler.num_negatives == 0
        {
            return Err(ConfigError("sampler.num_negatives must be > 0".into()));
        }
        if self.sampler.num_negatives >= self.model.num_classes {
            return Err(ConfigError(format!(
                "sampler.num_negatives ({}) must be < model.num_classes ({})",
                self.sampler.num_negatives, self.model.num_classes
            )));
        }
        if matches!(self.sampler.kind, SamplerKind::Rff)
            && self.sampler.dim == 0
        {
            return Err(ConfigError("sampler.dim must be > 0 for rff".into()));
        }
        if self.sampler.max_capacity != 0
            && self.sampler.max_capacity < self.model.num_classes
        {
            return Err(ConfigError(format!(
                "sampler.max_capacity ({}) must be 0 or >= model.num_classes ({})",
                self.sampler.max_capacity, self.model.num_classes
            )));
        }
        if self.serving.max_batch == 0 {
            return Err(ConfigError("serving.max_batch must be > 0".into()));
        }
        if self.serving.listen.is_empty() {
            return Err(ConfigError(
                "serving.listen must be a host:port bind address".into(),
            ));
        }
        if self.cluster.request_timeout_ms == 0 {
            return Err(ConfigError(
                "cluster.request_timeout_ms must be > 0".into(),
            ));
        }
        if self.cluster.virtual_nodes == 0 {
            return Err(ConfigError("cluster.virtual_nodes must be > 0".into()));
        }
        if self.train.batch_size == 0 {
            return Err(ConfigError("train.batch_size must be > 0".into()));
        }
        if self.train.pipeline_depth == 0 {
            return Err(ConfigError("train.pipeline_depth must be > 0".into()));
        }
        Ok(())
    }

    /// Serialize to JSON (for run manifests / EXPERIMENTS.md records).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "model",
                Json::obj(vec![
                    ("kind", Json::from(self.model.kind.name())),
                    ("num_classes", Json::from(self.model.num_classes)),
                    ("embed_dim", Json::from(self.model.embed_dim)),
                    ("hidden_dim", Json::from(self.model.hidden_dim)),
                    ("seq_len", Json::from(self.model.seq_len)),
                    ("feature_dim", Json::from(self.model.feature_dim)),
                    ("nnz", Json::from(self.model.nnz)),
                    ("tau", Json::from(self.model.tau as f64)),
                    ("normalize", Json::from(self.model.normalize)),
                ]),
            ),
            (
                "sampler",
                Json::obj(vec![
                    ("kind", Json::from(self.sampler.kind.name())),
                    ("num_negatives", Json::from(self.sampler.num_negatives)),
                    ("dim", Json::from(self.sampler.dim)),
                    ("nu", Json::from(self.sampler.nu as f64)),
                    ("feature_map", Json::from(self.sampler.feature_map.name())),
                    ("alpha", Json::from(self.sampler.alpha as f64)),
                    ("absolute", Json::from(self.sampler.absolute)),
                    (
                        "share_across_batch",
                        Json::from(self.sampler.share_across_batch),
                    ),
                    ("shards", Json::from(self.sampler.shards)),
                    ("max_capacity", Json::from(self.sampler.max_capacity)),
                    ("rebalance", Json::from(self.sampler.rebalance)),
                    ("quantize", Json::from(self.sampler.quantize.name())),
                    ("seed", Json::from(self.sampler.seed as usize)),
                ]),
            ),
            (
                "serving",
                Json::obj(vec![
                    ("double_buffer", Json::from(self.serving.double_buffer)),
                    ("max_batch", Json::from(self.serving.max_batch)),
                    ("max_wait_us", Json::from(self.serving.max_wait_us as usize)),
                    ("listen", Json::from(self.serving.listen.as_str())),
                ]),
            ),
            (
                "cluster",
                Json::obj(vec![
                    ("replicas", Json::from(self.cluster.replicas.as_str())),
                    (
                        "request_timeout_ms",
                        Json::from(self.cluster.request_timeout_ms as usize),
                    ),
                    ("hedge", Json::from(self.cluster.hedge)),
                    ("virtual_nodes", Json::from(self.cluster.virtual_nodes)),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("backend", Json::from(self.train.backend.name())),
                    ("batch_size", Json::from(self.train.batch_size)),
                    ("steps", Json::from(self.train.steps)),
                    ("lr", Json::from(self.train.lr as f64)),
                    ("optimizer", Json::from(self.train.optimizer.name())),
                    ("grad_clip", Json::from(self.train.grad_clip as f64)),
                    ("eval_every", Json::from(self.train.eval_every)),
                    ("eval_batches", Json::from(self.train.eval_batches)),
                    ("workers", Json::from(self.train.workers)),
                    ("pipeline_depth", Json::from(self.train.pipeline_depth)),
                    ("seed", Json::from(self.train.seed as usize)),
                ]),
            ),
            (
                "data",
                Json::obj(vec![
                    ("dataset", Json::from(self.data.dataset.as_str())),
                    ("zipf_s", Json::from(self.data.zipf_s)),
                    ("markov_rank", Json::from(self.data.markov_rank)),
                    ("markov_weight", Json::from(self.data.markov_weight)),
                    ("train_size", Json::from(self.data.train_size)),
                    ("valid_size", Json::from(self.data.valid_size)),
                    (
                        "labels_per_example",
                        Json::from(self.data.labels_per_example),
                    ),
                    ("latent_dim", Json::from(self.data.latent_dim)),
                    ("clusters", Json::from(self.data.clusters)),
                    ("noise", Json::from(self.data.noise)),
                    ("seed", Json::from(self.data.seed as usize)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn set_and_get_round_trip() {
        let mut c = Config::default();
        c.set("model.num_classes", "5000").unwrap();
        c.set("sampler.kind", "quadratic").unwrap();
        c.set("train.lr", "0.25").unwrap();
        c.set("data.zipf_s", "1.5").unwrap();
        assert_eq!(c.model.num_classes, 5000);
        assert_eq!(c.sampler.kind, SamplerKind::Quadratic);
        assert!((c.train.lr - 0.25).abs() < 1e-6);
        assert_eq!(c.data.zipf_s, 1.5);
    }

    #[test]
    fn temperature_maps_to_tau() {
        let mut c = Config::default();
        c.set("model.temperature", "0.5").unwrap();
        assert!((c.model.tau - 4.0).abs() < 1e-5);
        c.set("sampler.T", "0.5").unwrap();
        assert!((c.sampler.nu - 4.0).abs() < 1e-5);
    }

    #[test]
    fn serving_keys_round_trip() {
        let mut c = Config::default();
        // On by default since PR 3 (ROADMAP flip, gated on the
        // stream-exact equivalence tests).
        assert!(c.serving.double_buffer);
        assert_eq!(c.serving.listen, "127.0.0.1:0");
        c.set("serving.double_buffer", "false").unwrap();
        c.set("serving.max_batch", "64").unwrap();
        c.set("serving.max_wait_us", "500").unwrap();
        c.set("serving.listen", "0.0.0.0:7411").unwrap();
        assert!(!c.serving.double_buffer);
        assert_eq!(c.serving.max_batch, 64);
        assert_eq!(c.serving.max_wait_us, 500);
        assert_eq!(c.serving.listen, "0.0.0.0:7411");
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert!(!c2.serving.double_buffer);
        assert_eq!(c2.serving.max_batch, 64);
        assert_eq!(c2.serving.max_wait_us, 500);
        assert_eq!(c2.serving.listen, "0.0.0.0:7411");
        c.serving.max_batch = 0;
        assert!(c.validate().is_err());
        c.serving.max_batch = 32;
        c.serving.listen = String::new();
        assert!(c.validate().is_err());
    }

    #[test]
    fn cluster_keys_round_trip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.cluster.replicas, "");
        assert_eq!(c.cluster.request_timeout_ms, 1000);
        assert!(!c.cluster.hedge);
        assert_eq!(c.cluster.virtual_nodes, 64);
        c.set("cluster.replicas", "tcp:127.0.0.1:7411,tcp:127.0.0.1:7412")
            .unwrap();
        c.set("cluster.request_timeout_ms", "250").unwrap();
        c.set("cluster.hedge", "true").unwrap();
        c.set("cluster.virtual_nodes", "128").unwrap();
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(
            c2.cluster.replicas,
            "tcp:127.0.0.1:7411,tcp:127.0.0.1:7412"
        );
        assert_eq!(c2.cluster.request_timeout_ms, 250);
        assert!(c2.cluster.hedge);
        assert_eq!(c2.cluster.virtual_nodes, 128);
        c.cluster.request_timeout_ms = 0;
        assert!(c.validate().is_err());
        c.cluster.request_timeout_ms = 1000;
        c.cluster.virtual_nodes = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn vocab_knobs_round_trip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.sampler.max_capacity, 0);
        assert!((c.sampler.rebalance - 4.0).abs() < 1e-12);
        c.set("sampler.max_capacity", "50000").unwrap();
        c.set("sampler.rebalance", "2.5").unwrap();
        assert_eq!(c.sampler.max_capacity, 50_000);
        assert!((c.sampler.rebalance - 2.5).abs() < 1e-12);
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.sampler.max_capacity, 50_000);
        assert!((c2.sampler.rebalance - 2.5).abs() < 1e-12);
        // A nonzero capacity below n is a config error.
        c.sampler.max_capacity = 100;
        c.model.num_classes = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn quantize_knob_round_trips_and_rejects_garbage() {
        use crate::linalg::QuantizeKind;
        let mut c = Config::default();
        assert_eq!(c.sampler.quantize, QuantizeKind::None);
        c.set("sampler.quantize", "f16").unwrap();
        assert_eq!(c.sampler.quantize, QuantizeKind::F16);
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.sampler.quantize, QuantizeKind::F16);
        c.set("sampler.quantize", "i8").unwrap();
        assert_eq!(c.sampler.quantize, QuantizeKind::I8);
        c.set("sampler.quantize", "none").unwrap();
        assert_eq!(c.sampler.quantize, QuantizeKind::None);
        assert!(c.set("sampler.quantize", "f8").is_err());
    }

    #[test]
    fn train_backend_round_trips_and_rejects_garbage() {
        let mut c = Config::default();
        assert_eq!(c.train.backend, TrainBackend::Native);
        c.set("train.backend", "pjrt").unwrap();
        assert_eq!(c.train.backend, TrainBackend::Pjrt);
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.train.backend, TrainBackend::Pjrt);
        c.set("train.backend", "native").unwrap();
        assert_eq!(c.train.backend, TrainBackend::Native);
        assert!(c.set("train.backend", "xla").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        assert!(c.set("model.bogus", "1").is_err());
    }

    #[test]
    fn json_round_trip() {
        let mut c = Config::default();
        c.set("model.num_classes", "123").unwrap();
        c.set("sampler.dim", "77").unwrap();
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.model.num_classes, 123);
        assert_eq!(c2.sampler.dim, 77);
    }

    #[test]
    fn validation_catches_bad_m() {
        let mut c = Config::default();
        c.model.num_classes = 10;
        c.sampler.num_negatives = 10;
        assert!(c.validate().is_err());
    }

    #[test]
    fn load_applies_overrides() {
        let dir = std::env::temp_dir().join("rfsm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"model": {"num_classes": 400}}"#).unwrap();
        let cfg = Config::load(
            Some(p.to_str().unwrap()),
            vec![("model.embed_dim".to_string(), "64".to_string())].into_iter(),
        )
        .unwrap();
        assert_eq!(cfg.model.num_classes, 400);
        assert_eq!(cfg.model.embed_dim, 64);
    }
}
