//! Softmax / sampled-softmax loss math in pure Rust (f64).
//!
//! These are the *oracle* implementations: the training hot path runs the
//! AOT-compiled HLO (L1/L2), while this module provides
//!
//! * the exact full-softmax loss/gradient for evaluation,
//! * the sampled-softmax loss with the logit adjustment
//!   `o′_{i+1} = o_{s_i} − log(m·q_{s_i})` (paper eq. 5–6),
//! * the absolute-softmax variant used by the Quadratic baseline
//!   (paper §4.1),
//! * gradients **in logit space** (`∇_{o} L`), which is the coordinate
//!   system of Theorem 1's bias analysis (`∇_θ o_i = e_i`, `M = 1`) and is
//!   what the [`crate::bias`] harness integrates against.

use crate::linalg::logsumexp;

/// Full softmax cross-entropy loss: `L = −o_t + log Σ_j e^{o_j}`
/// (paper eq. 3). Returns the loss and the softmax pmf.
pub fn full_softmax_loss(logits: &[f64], target: usize) -> (f64, Vec<f64>) {
    assert!(target < logits.len());
    let lse = logsumexp(logits);
    let p = logits.iter().map(|&o| (o - lse).exp()).collect();
    (lse - logits[target], p)
}

/// Gradient of the full softmax loss w.r.t. the logits:
/// `∂L/∂o_i = p_i − 1{i = t}` (paper eq. 4 in logit coordinates).
pub fn full_softmax_grad(logits: &[f64], target: usize) -> Vec<f64> {
    let (_, mut p) = full_softmax_loss(logits, target);
    p[target] -= 1.0;
    p
}

/// Result of a sampled-softmax forward/backward pass.
#[derive(Clone, Debug)]
pub struct SampledLoss {
    /// `L′ = −o_t + log Z′` (paper eq. 6).
    pub loss: f64,
    /// Adjusted logits `[o_t, o_{s_1} − log(m q_1), …]` (paper eq. 5).
    pub adjusted: Vec<f64>,
    /// Sampled softmax pmf `p′` over `[target, s_1, …, s_m]`.
    pub probs: Vec<f64>,
    /// `∂L′/∂o` over the same coordinates: `p′ − e_target`.
    pub grad: Vec<f64>,
    /// The unbiased partition-function estimate `Z′`.
    pub z_estimate: f64,
}

/// Sampled softmax loss (paper §1.1). Inputs:
/// * `target_logit` — `o_t`,
/// * `neg_logits[i]` — `o_{s_i}` for each sampled negative,
/// * `q[i]` — the sampling probability of `s_i` (must be > 0),
///
/// The adjustment divides each negative's weight by `m·q_i`, making
/// `Z′ = e^{o_t} + (1/m)Σ e^{o_{s_i}}/q_{s_i}` an unbiased estimator of
/// the true partition function restricted appropriately (paper eq. 5).
pub fn sampled_softmax_loss(
    target_logit: f64,
    neg_logits: &[f64],
    q: &[f64],
) -> SampledLoss {
    let m = neg_logits.len();
    assert_eq!(q.len(), m, "sampled_softmax_loss: q length mismatch");
    assert!(m > 0, "sampled_softmax_loss: need at least one negative");
    let log_m = (m as f64).ln();
    let mut adjusted = Vec::with_capacity(m + 1);
    adjusted.push(target_logit);
    for (o, &qi) in neg_logits.iter().zip(q.iter()) {
        assert!(qi > 0.0, "sampled_softmax_loss: q must be positive");
        adjusted.push(o - (log_m + qi.ln()));
    }
    let lse = logsumexp(&adjusted);
    let probs: Vec<f64> = adjusted.iter().map(|&a| (a - lse).exp()).collect();
    let mut grad = probs.clone();
    grad[0] -= 1.0;
    SampledLoss {
        loss: lse - target_logit,
        z_estimate: lse.exp(),
        adjusted,
        probs,
        grad,
    }
}

/// The absolute-softmax transform used by the Quadratic baseline
/// (paper §4.1): logits are replaced by their absolute values before the
/// softmax, matching what the quadratic kernel `αo²+β` can approximate.
pub fn absolute_logits(logits: &[f64]) -> Vec<f64> {
    logits.iter().map(|o| o.abs()).collect()
}

/// Map the sampled-softmax logit gradient back to the full `ℝⁿ` logit
/// space: coordinates of duplicated sampled ids accumulate.
/// (`ids` are the sampled class ids; `grad` is [`SampledLoss::grad`].)
pub fn scatter_grad(
    n: usize,
    target: usize,
    ids: &[u32],
    grad: &[f64],
) -> Vec<f64> {
    assert_eq!(grad.len(), ids.len() + 1);
    let mut out = vec![0.0; n];
    out[target] += grad[0];
    for (&id, &g) in ids.iter().zip(&grad[1..]) {
        out[id as usize] += g;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::propkit::{check, close, gen};
    use crate::rng::Rng;

    #[test]
    fn full_loss_matches_manual() {
        let logits = [1.0, 2.0, 3.0];
        let (loss, p) = full_softmax_loss(&logits, 2);
        let z: f64 = logits.iter().map(|o| o.exp()).sum();
        assert!((loss - (z.ln() - 3.0)).abs() < 1e-12);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn full_grad_sums_to_zero() {
        check("full-grad-sum-zero", |rng| {
            let n = gen::usize_in(rng, 2, 30);
            let logits: Vec<f64> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
            let t = rng.index(n);
            let g = full_softmax_grad(&logits, t);
            let s: f64 = g.iter().sum();
            prop_assert!(close(s, 0.0, 0.0, 1e-9), "Σgrad = {s}");
            prop_assert!(g[t] < 0.0, "target grad must be negative");
            Ok(())
        });
    }

    #[test]
    fn sampled_loss_reduces_to_full_when_all_sampled() {
        // m draws covering exactly the negative set with q = exact
        // conditional softmax ⇒ E[Z′] = Z; with q_i ∝ e^{o_i} AND the
        // specific realization being one-of-each this won't equal exactly,
        // but with m→∞ the loss converges. Here: verify the m=|N| uniform
        // case against direct computation of the adjusted formula.
        let logits = [0.5, -0.3, 0.9, 0.1];
        let t = 0;
        let negs = [logits[1], logits[2], logits[3]];
        let q = [1.0 / 3.0; 3];
        let s = sampled_softmax_loss(logits[t], &negs, &q);
        // adjustment: o − log(3·(1/3)) = o ⇒ identical to full loss.
        let (full, _) = full_softmax_loss(&logits, t);
        assert!((s.loss - full).abs() < 1e-12, "{} vs {full}", s.loss);
    }

    #[test]
    fn z_estimate_is_unbiased() {
        // E_q[Z′] = e^{o_t} + Σ_j e^{o_j}·(q over negatives)·(1/q_j)/m·m …
        // empirical check of eq. 5's unbiasedness under a skewed q.
        let mut rng = Rng::seeded(121);
        let n = 12;
        let logits: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let t = 3;
        let weights: Vec<f64> = (0..n)
            .map(|i| if i == t { 0.0 } else { (i + 1) as f64 })
            .collect();
        let wsum: f64 = weights.iter().sum();
        let q_of = |i: usize| weights[i] / wsum;
        let z_true: f64 = logits.iter().map(|o| o.exp()).sum();
        let m = 20;
        let trials = 20_000;
        let mut acc = 0.0;
        let table = crate::rng::AliasTable::new(&weights);
        for _ in 0..trials {
            let ids: Vec<usize> =
                (0..m).map(|_| table.sample(&mut rng)).collect();
            let negs: Vec<f64> = ids.iter().map(|&i| logits[i]).collect();
            let qs: Vec<f64> = ids.iter().map(|&i| q_of(i)).collect();
            let s = sampled_softmax_loss(logits[t], &negs, &qs);
            acc += s.z_estimate;
        }
        let z_hat = acc / trials as f64;
        // Z' estimates e^{o_t} + Σ_{j≠t} e^{o_j} = Z.
        assert!(
            (z_hat - z_true).abs() / z_true < 0.02,
            "E[Z′] = {z_hat} vs Z = {z_true}"
        );
    }

    #[test]
    fn sampled_grad_structure() {
        check("sampled-grad", |rng| {
            let m = gen::usize_in(rng, 1, 30);
            let o_t = rng.gaussian();
            let negs: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let q: Vec<f64> = (0..m).map(|_| rng.f64_open()).collect();
            let s = sampled_softmax_loss(o_t, &negs, &q);
            let gsum: f64 = s.grad.iter().sum();
            prop_assert!(close(gsum, 0.0, 0.0, 1e-9), "Σgrad = {gsum}");
            prop_assert!(s.grad[0] <= 0.0, "target grad positive");
            prop_assert!(
                s.grad[1..].iter().all(|&g| g >= 0.0),
                "negative grads must be ≥ 0"
            );
            prop_assert!(s.loss.is_finite(), "loss not finite");
            Ok(())
        });
    }

    #[test]
    fn grad_matches_finite_difference() {
        let o_t = 0.4;
        let negs = [0.1, -0.2, 0.7];
        let q = [0.2, 0.5, 0.3];
        let s = sampled_softmax_loss(o_t, &negs, &q);
        let eps = 1e-6;
        // d/do_t
        let lp = sampled_softmax_loss(o_t + eps, &negs, &q).loss;
        let lm = sampled_softmax_loss(o_t - eps, &negs, &q).loss;
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - s.grad[0]).abs() < 1e-6, "{fd} vs {}", s.grad[0]);
        // d/do_{s_1}
        let mut np = negs;
        np[1] += eps;
        let mut nm = negs;
        nm[1] -= eps;
        let fd1 = (sampled_softmax_loss(o_t, &np, &q).loss
            - sampled_softmax_loss(o_t, &nm, &q).loss)
            / (2.0 * eps);
        assert!((fd1 - s.grad[2]).abs() < 1e-6);
    }

    #[test]
    fn absolute_transform() {
        assert_eq!(absolute_logits(&[-1.0, 2.0, -0.5]), vec![1.0, 2.0, 0.5]);
    }

    #[test]
    fn scatter_accumulates_duplicates() {
        let g = scatter_grad(5, 0, &[2, 2, 4], &[-0.9, 0.3, 0.3, 0.3]);
        assert!((g[0] + 0.9).abs() < 1e-12);
        assert!((g[2] - 0.6).abs() < 1e-12);
        assert!((g[4] - 0.3).abs() < 1e-12);
        assert_eq!(g[1], 0.0);
    }

    #[test]
    fn stability_under_large_logits() {
        let s = sampled_softmax_loss(500.0, &[499.0, 501.0], &[0.5, 0.5]);
        assert!(s.loss.is_finite());
        assert!(s.probs.iter().all(|p| p.is_finite()));
    }
}
