//! Socket-agnostic stream substrate for the serving transport.
//!
//! The frame codec in [`super::wire`] reads and writes through generic
//! `Read + Write` streams, so the transport server/client are
//! parameterized over the *kind* of socket by the two small enums here:
//! [`Stream`] (a connected byte stream) and [`Listener`] (an accepting
//! endpoint), each delegating to the `std` unix-domain or TCP primitive.
//! An enum — not a trait object — because the server needs concrete
//! capabilities (`try_clone`, `shutdown`, nonblocking accept) that `dyn
//! Read + Write` cannot offer, and std-only rules out a generic
//! `mio`-style abstraction.
//!
//! TCP streams get `TCP_NODELAY` set on both the accept and connect
//! paths: the wire protocol writes whole frames (and whole batched
//! waves) with single `write_all` calls, so Nagle's algorithm could only
//! add latency, never useful coalescing — the batching already happened
//! at the frame layer.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;

/// Where a [`super::TransportServer`] is reachable: a unix-socket path
/// on this machine, or a TCP address that may cross machines. For TCP
/// this is the *actual* bound address — binding `serving.listen =
/// "127.0.0.1:0"` yields the kernel-assigned port, so tests and benches
/// can run loopback listeners without port coordination.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Uds(PathBuf),
    Tcp(SocketAddr),
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Uds(p) => write!(f, "uds:{}", p.display()),
            Endpoint::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

impl Endpoint {
    /// Parse the endpoint syntax used across the CLI and the cluster
    /// config: `tcp:HOST:PORT`, `uds:PATH`, or a bare value (a '/' means
    /// a socket path, anything else a TCP address). TCP hosts resolve
    /// through `ToSocketAddrs`; the first resolved address wins.
    pub fn parse(spec: &str) -> std::io::Result<Endpoint> {
        let tcp = |addr: &str| -> std::io::Result<Endpoint> {
            let resolved = addr.to_socket_addrs()?.next().ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    format!("endpoint '{addr}' resolved to no address"),
                )
            })?;
            Ok(Endpoint::Tcp(resolved))
        };
        if let Some(addr) = spec.strip_prefix("tcp:") {
            tcp(addr)
        } else if let Some(path) = spec.strip_prefix("uds:") {
            Ok(Endpoint::Uds(PathBuf::from(path)))
        } else if spec.contains('/') {
            Ok(Endpoint::Uds(PathBuf::from(spec)))
        } else {
            tcp(spec)
        }
    }
}

/// One connected byte stream of either flavor. Implements `Read`/`Write`
/// by delegation so the [`super::wire`] codecs are oblivious to the
/// underlying socket kind.
#[derive(Debug)]
pub(crate) enum Stream {
    Uds(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    /// Connect to an endpoint (TCP connects get `TCP_NODELAY`).
    pub(crate) fn connect(endpoint: &Endpoint) -> std::io::Result<Stream> {
        match endpoint {
            Endpoint::Uds(p) => Ok(Stream::Uds(UnixStream::connect(p)?)),
            Endpoint::Tcp(a) => {
                let s = TcpStream::connect(a)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
        }
    }

    /// Connect to a TCP address given in any `ToSocketAddrs` form.
    pub(crate) fn connect_tcp(
        addr: impl ToSocketAddrs,
    ) -> std::io::Result<Stream> {
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Ok(Stream::Tcp(s))
    }

    /// Connect with a deadline. TCP uses the kernel's connect timeout;
    /// unix sockets have no std connect timeout, but a local listener
    /// either accepts immediately or the path is gone — the connect
    /// cannot hang the way a dead TCP peer can, so the blocking connect
    /// is an acceptable fallback there.
    pub(crate) fn connect_timeout(
        endpoint: &Endpoint,
        timeout: std::time::Duration,
    ) -> std::io::Result<Stream> {
        match endpoint {
            Endpoint::Uds(p) => Ok(Stream::Uds(UnixStream::connect(p)?)),
            Endpoint::Tcp(a) => {
                let s = TcpStream::connect_timeout(a, timeout)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
        }
    }

    /// Arm (or with `None` disarm) a read deadline on the socket. A
    /// read that trips it fails with `WouldBlock`/`TimedOut`, which the
    /// wire layer types as `ProtocolError::Timeout`.
    pub(crate) fn set_read_timeout(
        &self,
        timeout: Option<std::time::Duration>,
    ) -> std::io::Result<()> {
        match self {
            Stream::Uds(s) => s.set_read_timeout(timeout),
            Stream::Tcp(s) => s.set_read_timeout(timeout),
        }
    }

    pub(crate) fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Stream::Uds(s) => Stream::Uds(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }

    pub(crate) fn shutdown(
        &self,
        how: std::net::Shutdown,
    ) -> std::io::Result<()> {
        match self {
            Stream::Uds(s) => s.shutdown(how),
            Stream::Tcp(s) => s.shutdown(how),
        }
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Uds(s) => s.set_nonblocking(nb),
            Stream::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Uds(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Uds(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Uds(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// An accepting endpoint of either flavor, nonblocking so the accept
/// loop can poll for shutdown.
pub(crate) enum Listener {
    Uds(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    pub(crate) fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Uds(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    /// Accept one connection (TCP accepts get `TCP_NODELAY`).
    pub(crate) fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Uds(l) => Ok(Stream::Uds(l.accept()?.0)),
            Listener::Tcp(l) => {
                let (s, _addr) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
        }
    }
}
