//! Length-prefixed binary wire protocol for the serving transport —
//! std-only, little-endian, versioned, socket-agnostic (the codecs read
//! and write through generic `Read + Write` streams; see [`super::net`]
//! for the unix-socket/TCP stream substrate they run over).
//!
//! ## Frame layout
//!
//! ```text
//! bytes 0..2   magic  "RF"
//! byte  2      protocol version (2 for single-request frames, 3 for waves
//!              and STATS)
//! byte  3      frame kind (request 0x01..0x04, admin 0x10..0x12, wave 0x20,
//!              response 0x81..0x92, response wave 0xA0, error 0xFF)
//! bytes 4..12  request id (u64 LE; echoed on the response, 0 = connection-level;
//!              unused on wave frames — sub-request ids are authoritative)
//! bytes 12..16 payload length (u32 LE, ≤ MAX_PAYLOAD)
//! bytes 16..   payload (kind-specific, exact length — trailing bytes are malformed)
//! ```
//!
//! ## Batched wave frames (wire v3)
//!
//! A pipelined burst can ride in ONE `Wave` frame instead of one frame
//! per request: the payload is `u32 count` followed by `count`
//! sub-requests, each `u64 id | u8 kind | u32 len | payload[len]` with
//! the *same* per-kind payload encoding as the standalone frame. The
//! receiver parses one 16-byte header (and runs one length/magic/version
//! check) per wave rather than per request, and the server submits the
//! whole decoded wave to the micro-batcher as one coalesced batch.
//! Responses travel back the same way (`0xA0`), sub-ids preserved, and a
//! failing sub-request yields an `Error` *sub-response* in its slot —
//! partial failure never poisons the rest of the wave. Counts are
//! overflow-guarded: `count` is bounded by [`MAX_WAVE`] and validated
//! against the delivered payload *before* any allocation, and nested
//! waves are malformed. Wave frames carry version 3; single frames keep
//! encoding at version 2, so a v2 peer interoperates untouched as long
//! as nobody sends it waves.
//!
//! ## Payloads
//!
//! * `Sample` request: `u32 dim | f32×dim h | u32 m | u64 seed`
//! * `Probability` request: `u32 dim | f32×dim h | u32 class`
//! * `TopK` request: `u32 dim | f32×dim h | u32 k`
//! * `Mass` request (v3): `u32 dim | f32×dim h`
//! * `Mass` response (v3): `u64 epoch | f64 mass`
//! * `AddClasses` admin request: `u32 rows | u32 dim | f32×rows·dim embeddings`
//! * `RetireClasses` admin request: `u32 count | u32×count ids`
//! * `Stats` admin request (v3): empty payload
//! * `Stats` response (v3): `u32 len | utf8×len json snapshot`
//! * `Sample` response: `u64 epoch | u32 count | u32×count ids | f64×count probs`
//! * `Probability` response: `u64 epoch | f64 q`
//! * `TopK` response: `u64 epoch | u32 count | (u32 id, f64 q)×count`
//! * `AddClasses` response: `u64 epoch | u32 count | u32×count assigned ids`
//! * `RetireClasses` response: `u64 epoch | u32 retired-count`
//! * `Error` response: `u8 code | u16 len | utf8×len message`
//!
//! Per-request seeds ride the wire inside `Sample` requests, so served
//! draws are deterministic across process boundaries: the same (seed,
//! query, epoch) yields byte-identical draws in-process and remotely.
//!
//! The `ADD_CLASSES`/`RETIRE_CLASSES` **admin frames** (wire version 2)
//! drive the mutable class universe cross-process: the server applies
//! them through the sampler writer as epoch-versioned snapshot swaps and
//! echoes the new epoch, so a churn driver on one machine can grow the
//! universe another machine is serving from.
//!
//! Framing violations decode to a typed [`ProtocolError`]; the server
//! answers with one best-effort `Error` frame (code
//! [`ERR_PROTOCOL`], request id 0) and closes the connection — a
//! malformed peer can never poison the batcher or other connections.
//!
//! Encoders write straight into a caller-supplied buffer (header first,
//! payload appended, length backfilled) — no per-frame payload `Vec` —
//! so a connection writer can stream thousands of response frames per
//! wave from one reused allocation (`frame_encode_us` vs
//! `frame_encode_fresh_us` in `serve-bench` reports the delta).

use crate::sampler::ServeQuery;
use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// Greatest protocol version this build speaks. v2 added the
/// `ADD_CLASSES`/`RETIRE_CLASSES` admin frames and [`ERR_OVERLOAD`]; v3
/// added the batched wave frames. Headers carrying
/// [`MIN_WIRE_VERSION`]`..=`[`WIRE_VERSION`] are accepted; anything else
/// is refused with [`ProtocolError::UnknownVersion`]. Single frames
/// still *encode* at v2 (only wave frames need v3), so v2 peers keep
/// interoperating in both directions.
pub const WIRE_VERSION: u8 = 3;

/// Oldest protocol version still accepted.
pub const MIN_WIRE_VERSION: u8 = 2;

/// Version written on single-request/response frames: the lowest version
/// whose peers understand them, so a v3 build stays wire-compatible with
/// v2 peers on everything except waves.
const SINGLE_FRAME_VERSION: u8 = 2;

/// Version a wave frame requires (and is encoded with).
const WAVE_FRAME_VERSION: u8 = 3;

/// Hard cap on sub-requests (or sub-responses) in one wave frame — far
/// above any useful coalescing depth, small enough that a hostile count
/// prefix cannot balloon memory before the per-sub length checks run.
pub const MAX_WAVE: usize = 4096;

/// Soft byte bound senders apply per wave frame: once a wave's encoding
/// crosses it, the wave closes and the remaining sub-frames continue in
/// the next frame. Shared by the client's request chunking and the
/// server's reply packing so the boundary rule cannot drift between
/// them, and sized so no frame ever approaches [`MAX_PAYLOAD`] (whose
/// violation kills the connection). Real queries (dim ≤ 10⁴ floats ≈
/// 40 KiB) pack dozens of subs per frame before this binds.
pub const WAVE_SOFT_PAYLOAD: usize = 1 << 20;

/// Frame magic (catches peers speaking a different protocol entirely).
pub const MAGIC: [u8; 2] = *b"RF";

/// Header length in bytes.
pub const HEADER_LEN: usize = 16;

/// Hard cap on payload length: 16 MiB — far above any real query
/// (`dim ≤ 10⁴` floats) but small enough that a hostile length prefix
/// cannot balloon server memory.
pub const MAX_PAYLOAD: usize = 16 << 20;

/// Error-frame code: framing/versioning violation; the sender closes the
/// connection after this frame.
pub const ERR_PROTOCOL: u8 = 1;
/// Error-frame code: this request failed in the sampler (e.g. a query
/// dimension the feature map rejects); the connection stays usable.
pub const ERR_SERVE: u8 = 2;
/// Error-frame code: server is shutting down.
pub const ERR_SHUTDOWN: u8 = 3;
/// Error-frame code: this connection exceeded its in-flight request cap
/// (backpressure); the request was **not** served. The connection stays
/// usable — retry after draining pending replies.
pub const ERR_OVERLOAD: u8 = 4;

const KIND_REQ_SAMPLE: u8 = 0x01;
const KIND_REQ_PROBABILITY: u8 = 0x02;
const KIND_REQ_TOP_K: u8 = 0x03;
const KIND_REQ_MASS: u8 = 0x04;
const KIND_REQ_ADD_CLASSES: u8 = 0x10;
const KIND_REQ_RETIRE_CLASSES: u8 = 0x11;
const KIND_REQ_STATS: u8 = 0x12;
const KIND_REQ_SNAPSHOT: u8 = 0x13;
const KIND_REQ_WAVE: u8 = 0x20;
const KIND_RESP_SAMPLE: u8 = 0x81;
const KIND_RESP_PROBABILITY: u8 = 0x82;
const KIND_RESP_TOP_K: u8 = 0x83;
const KIND_RESP_MASS: u8 = 0x84;
const KIND_RESP_ADD_CLASSES: u8 = 0x90;
const KIND_RESP_RETIRE_CLASSES: u8 = 0x91;
const KIND_RESP_STATS: u8 = 0x92;
const KIND_RESP_SNAPSHOT: u8 = 0x93;
const KIND_RESP_WAVE: u8 = 0xA0;
const KIND_RESP_ERROR: u8 = 0xFF;

/// Largest snapshot-chunk `data` length a [`Response::SnapshotChunk`]
/// frame can carry: [`MAX_PAYLOAD`] minus the chunk's fixed prefix
/// (`u64 epoch | u64 total | u64 offset | u32 len`). Servers clamp their
/// chunking to this; clients requesting `max_chunk = 0` get it as the
/// default.
pub const MAX_SNAPSHOT_CHUNK: usize = MAX_PAYLOAD - 28;

/// Version the `STATS` admin frames require (added in wire v3 alongside
/// waves): a `STATS` kind stamped v2 decodes to
/// [`ProtocolError::UnknownKind`] — exactly the refusal a genuine v2
/// peer, which predates the kind, would produce — so telemetry scrapes
/// degrade identically against old and new builds.
const STATS_FRAME_VERSION: u8 = 3;

/// Bytes of the fixed per-sub-frame prefix inside a wave payload
/// (`u64 id | u8 kind | u32 len`) — the floor used to validate a wave's
/// count prefix against the delivered payload before allocating.
const WAVE_SUB_PREFIX: usize = 13;

/// Typed transport failure. Framing variants are fatal for the
/// connection ([`ProtocolError::closes_connection`]); `Remote` with
/// [`ERR_SERVE`] is a per-request failure the connection survives.
#[derive(Debug)]
pub enum ProtocolError {
    /// Peer closed (or the stream died) mid-frame.
    Truncated,
    /// Length prefix exceeds [`MAX_PAYLOAD`].
    Oversized { len: usize, max: usize },
    /// First two bytes were not [`MAGIC`].
    BadMagic([u8; 2]),
    /// Header carried a version this build does not speak.
    UnknownVersion(u8),
    /// Header carried an unknown (or directionally invalid) frame kind.
    UnknownKind(u8),
    /// Payload failed structural validation (length/content mismatch).
    Malformed(&'static str),
    /// Underlying socket error.
    Io(std::io::Error),
    /// A connect or read deadline expired before the peer answered.
    /// Fatal for the connection: a timed-out read may have consumed a
    /// partial frame, so the stream can never be resumed — callers
    /// reconnect (or fail over) instead.
    Timeout,
    /// The peer answered with an `Error` frame (client side).
    Remote { code: u8, message: String },
    /// Sync client got a response for a request it did not send.
    IdMismatch { sent: u64, got: u64 },
}

impl ProtocolError {
    /// Whether the connection must be torn down after this error. Only
    /// the per-request `Remote` failures — a serve rejection
    /// ([`ERR_SERVE`]) or backpressure shedding ([`ERR_OVERLOAD`]) —
    /// leave the stream usable.
    pub fn closes_connection(&self) -> bool {
        !matches!(
            self,
            ProtocolError::Remote { code: ERR_SERVE | ERR_OVERLOAD, .. }
        )
    }
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Truncated => write!(f, "truncated frame"),
            ProtocolError::Oversized { len, max } => {
                write!(f, "oversized frame: payload {len} > max {max}")
            }
            ProtocolError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?}")
            }
            ProtocolError::UnknownVersion(v) => {
                write!(
                    f,
                    "unknown wire version {v} (speaking \
                     {MIN_WIRE_VERSION}..={WIRE_VERSION})"
                )
            }
            ProtocolError::UnknownKind(k) => {
                write!(f, "unknown frame kind 0x{k:02x}")
            }
            ProtocolError::Malformed(why) => write!(f, "malformed frame: {why}"),
            ProtocolError::Io(e) => write!(f, "transport i/o: {e}"),
            ProtocolError::Timeout => {
                write!(f, "request timed out (peer dead or overloaded)")
            }
            ProtocolError::Remote { code, message } => {
                write!(f, "remote error (code {code}): {message}")
            }
            ProtocolError::IdMismatch { sent, got } => {
                write!(f, "response id {got} for request id {sent}")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

impl From<std::io::Error> for ProtocolError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            ErrorKind::UnexpectedEof => ProtocolError::Truncated,
            // Both kinds mean a socket deadline fired: unix sockets
            // report WouldBlock, TCP reports TimedOut (platform-
            // dependent) — callers see one typed Timeout either way.
            ErrorKind::WouldBlock | ErrorKind::TimedOut => {
                ProtocolError::Timeout
            }
            _ => ProtocolError::Io(e),
        }
    }
}

/// One decoded request: a serve query, or an admin mutation of the
/// served class universe.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Sample { h: Vec<f32>, m: u32, seed: u64 },
    Probability { h: Vec<f32>, class: u32 },
    TopK { h: Vec<f32>, k: u32 },
    /// Admin: append `rows` new classes (row-major embeddings, width
    /// `dim`); the response echoes the assigned ids and the epoch of the
    /// snapshot swap that made them visible.
    AddClasses { dim: u32, embeddings: Vec<f32> },
    /// Admin: retire the given live classes.
    RetireClasses { ids: Vec<u32> },
    /// Admin (wire v3): scrape the server's live telemetry snapshot.
    /// Empty payload; answered inline with [`Response::Stats`], never
    /// routed through the batcher.
    Stats,
    /// Wire v3: report the sampler's total proposal mass (partition
    /// function of the serving distribution) at the given query.
    /// Answered inline from the pinned snapshot, never batched — the
    /// cluster router's mass-weighted replica pick depends on it.
    Mass { h: Vec<f32> },
    /// Admin (wire v3): stream the server's full durable sampler state
    /// (the [`crate::snapshot`] binary encoding) as a sequence of
    /// [`Response::SnapshotChunk`] frames sharing this request's id.
    /// `max_chunk` caps the per-frame `data` length (`0` = the server's
    /// default, [`MAX_SNAPSHOT_CHUNK`]) — small values exist so tests
    /// and constrained links can force multi-chunk streams.
    SnapshotFetch { max_chunk: u32 },
}

impl Request {
    /// Whether this is an admin frame (universe mutation or telemetry
    /// scrape) rather than a serve query.
    pub fn is_admin(&self) -> bool {
        matches!(
            self,
            Request::AddClasses { .. }
                | Request::RetireClasses { .. }
                | Request::Stats
                | Request::Mass { .. }
                | Request::SnapshotFetch { .. }
        )
    }

    /// Split a serve query into the embedding and the batcher-level
    /// [`ServeQuery`] it maps to. Panics on admin frames (route those
    /// through the server's admin hook instead — see
    /// [`Request::is_admin`]).
    pub fn into_query(self) -> (Vec<f32>, ServeQuery) {
        match self {
            Request::Sample { h, m, seed } => {
                (h, ServeQuery::Sample { m: m as usize, seed })
            }
            Request::Probability { h, class } => {
                (h, ServeQuery::Probability { class: class as usize })
            }
            Request::TopK { h, k } => (h, ServeQuery::TopK { k: k as usize }),
            Request::AddClasses { .. }
            | Request::RetireClasses { .. }
            | Request::Stats
            | Request::Mass { .. }
            | Request::SnapshotFetch { .. } => {
                panic!("into_query: admin frame is not a serve query")
            }
        }
    }
}

/// One decoded response, epoch-tagged per the serving contract.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Sample { epoch: u64, ids: Vec<u32>, probs: Vec<f64> },
    Probability { epoch: u64, q: f64 },
    TopK { epoch: u64, items: Vec<(u32, f64)> },
    /// Admin ack: ids assigned to the appended classes, and the epoch at
    /// which they became visible.
    AddClasses { epoch: u64, ids: Vec<u32> },
    /// Admin ack: how many classes were retired, and the epoch at which
    /// the holes became visible.
    RetireClasses { epoch: u64, count: u32 },
    /// Telemetry snapshot (wire v3): a JSON document produced by the
    /// server's live metrics registry (`metrics::live`). Kept as a
    /// string on the wire so the protocol layer stays oblivious to the
    /// snapshot schema — consumers parse it with the in-crate `json`
    /// module.
    Stats { json: String },
    /// Total proposal mass at the queried embedding, epoch-tagged like
    /// every serve response (wire v3).
    Mass { epoch: u64, mass: f64 },
    /// One chunk of a streamed sampler-state snapshot (wire v3): bytes
    /// `offset..offset+data.len()` of a `total`-byte
    /// [`crate::snapshot`] encoding captured at `epoch`. All chunks of
    /// one fetch share the request id and arrive in offset order; the
    /// fetch is complete when `offset + data.len() == total`. `epoch`
    /// is identical across chunks — the server encodes once and streams
    /// the buffer, never a torn state.
    SnapshotChunk { epoch: u64, total: u64, offset: u64, data: Vec<u8> },
    Error { code: u8, message: String },
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

/// Append a frame header with a placeholder length; returns the offset
/// of the length field so [`finish_frame`] can backfill it once the
/// payload has been written in place — the zero-copy path: no per-frame
/// payload `Vec`, the caller's (reusable) buffer is the only allocation.
fn begin_frame(out: &mut Vec<u8>, version: u8, kind: u8, id: u64) -> usize {
    out.extend_from_slice(&MAGIC);
    out.push(version);
    out.push(kind);
    out.extend_from_slice(&id.to_le_bytes());
    let len_at = out.len();
    out.extend_from_slice(&0u32.to_le_bytes());
    len_at
}

/// Backfill the length field of the frame opened by [`begin_frame`].
fn finish_frame(out: &mut Vec<u8>, len_at: usize) {
    let payload_len = out.len() - (len_at + 4);
    debug_assert!(payload_len <= MAX_PAYLOAD);
    out[len_at..len_at + 4]
        .copy_from_slice(&(payload_len as u32).to_le_bytes());
}

fn push_query(payload: &mut Vec<u8>, h: &[f32]) {
    payload.extend_from_slice(&(h.len() as u32).to_le_bytes());
    for x in h {
        payload.extend_from_slice(&x.to_le_bytes());
    }
}

fn request_kind(req: &Request) -> u8 {
    match req {
        Request::Sample { .. } => KIND_REQ_SAMPLE,
        Request::Probability { .. } => KIND_REQ_PROBABILITY,
        Request::TopK { .. } => KIND_REQ_TOP_K,
        Request::Mass { .. } => KIND_REQ_MASS,
        Request::AddClasses { .. } => KIND_REQ_ADD_CLASSES,
        Request::RetireClasses { .. } => KIND_REQ_RETIRE_CLASSES,
        Request::Stats => KIND_REQ_STATS,
        Request::SnapshotFetch { .. } => KIND_REQ_SNAPSHOT,
    }
}

/// Wire version stamped on a single frame of the given kind: v2 for
/// everything a v2 peer understands, v3 for the kinds introduced with
/// wire v3 (`STATS`, `MASS`), so a v2 receiver refuses them on the
/// version byte rather than mis-parsing an unknown kind.
fn single_frame_version(kind: u8) -> u8 {
    if kind_requires_v3(kind) {
        STATS_FRAME_VERSION
    } else {
        SINGLE_FRAME_VERSION
    }
}

/// Append a request's kind-specific payload bytes — shared between the
/// single-frame encoder and the wave sub-frame encoder, so both paths
/// are byte-identical at the payload level.
fn encode_request_payload(out: &mut Vec<u8>, req: &Request) {
    match req {
        Request::Sample { h, m, seed } => {
            push_query(out, h);
            out.extend_from_slice(&m.to_le_bytes());
            out.extend_from_slice(&seed.to_le_bytes());
        }
        Request::Probability { h, class } => {
            push_query(out, h);
            out.extend_from_slice(&class.to_le_bytes());
        }
        Request::TopK { h, k } => {
            push_query(out, h);
            out.extend_from_slice(&k.to_le_bytes());
        }
        Request::AddClasses { dim, embeddings } => {
            debug_assert!(
                *dim as usize != 0 && embeddings.len() % *dim as usize == 0,
                "AddClasses: embeddings not row-major of width dim"
            );
            let rows = embeddings.len() as u32 / dim;
            out.extend_from_slice(&rows.to_le_bytes());
            out.extend_from_slice(&dim.to_le_bytes());
            for x in embeddings {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Request::RetireClasses { ids } => {
            out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for i in ids {
                out.extend_from_slice(&i.to_le_bytes());
            }
        }
        Request::Stats => {}
        Request::Mass { h } => push_query(out, h),
        Request::SnapshotFetch { max_chunk } => {
            out.extend_from_slice(&max_chunk.to_le_bytes());
        }
    }
}

/// Encode one request frame into `out` (appended in place — reuse one
/// buffer across frames for the zero-copy path).
pub fn encode_request(out: &mut Vec<u8>, id: u64, req: &Request) {
    let kind = request_kind(req);
    let len_at = begin_frame(out, single_frame_version(kind), kind, id);
    encode_request_payload(out, req);
    finish_frame(out, len_at);
}

fn response_kind(resp: &Response) -> u8 {
    match resp {
        Response::Sample { .. } => KIND_RESP_SAMPLE,
        Response::Probability { .. } => KIND_RESP_PROBABILITY,
        Response::TopK { .. } => KIND_RESP_TOP_K,
        Response::Mass { .. } => KIND_RESP_MASS,
        Response::AddClasses { .. } => KIND_RESP_ADD_CLASSES,
        Response::RetireClasses { .. } => KIND_RESP_RETIRE_CLASSES,
        Response::Stats { .. } => KIND_RESP_STATS,
        Response::SnapshotChunk { .. } => KIND_RESP_SNAPSHOT,
        Response::Error { .. } => KIND_RESP_ERROR,
    }
}

/// Append a response's kind-specific payload bytes (single-frame and
/// wave sub-frame encodings share this).
fn encode_response_payload(out: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Sample { epoch, ids, probs } => {
            debug_assert_eq!(ids.len(), probs.len());
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for i in ids {
                out.extend_from_slice(&i.to_le_bytes());
            }
            for q in probs {
                out.extend_from_slice(&q.to_le_bytes());
            }
        }
        Response::Probability { epoch, q } => {
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&q.to_le_bytes());
        }
        Response::TopK { epoch, items } => {
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for (i, q) in items {
                out.extend_from_slice(&i.to_le_bytes());
                out.extend_from_slice(&q.to_le_bytes());
            }
        }
        Response::AddClasses { epoch, ids } => {
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for i in ids {
                out.extend_from_slice(&i.to_le_bytes());
            }
        }
        Response::RetireClasses { epoch, count } => {
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&count.to_le_bytes());
        }
        Response::Mass { epoch, mass } => {
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&mass.to_le_bytes());
        }
        Response::Stats { json } => {
            let raw = json.as_bytes();
            debug_assert!(raw.len() <= MAX_PAYLOAD - 4);
            out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
            out.extend_from_slice(raw);
        }
        Response::SnapshotChunk { epoch, total, offset, data } => {
            debug_assert!(data.len() <= MAX_SNAPSHOT_CHUNK);
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&total.to_le_bytes());
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&(data.len() as u32).to_le_bytes());
            out.extend_from_slice(data);
        }
        Response::Error { code, message } => {
            let msg = message.as_bytes();
            let len = msg.len().min(u16::MAX as usize);
            out.push(*code);
            out.extend_from_slice(&(len as u16).to_le_bytes());
            out.extend_from_slice(&msg[..len]);
        }
    }
}

/// Encode one response frame into `out` (appended in place — reuse one
/// buffer across frames for the zero-copy path).
pub fn encode_response(out: &mut Vec<u8>, id: u64, resp: &Response) {
    let kind = response_kind(resp);
    let len_at = begin_frame(out, single_frame_version(kind), kind, id);
    encode_response_payload(out, resp);
    finish_frame(out, len_at);
}

// ---------------------------------------------------------------------------
// Wave (v3 multi-request) frame encoding
// ---------------------------------------------------------------------------

/// Incremental encoder for one wave frame: `begin_*` writes the header
/// and a placeholder count, each `push_*` appends one sub-frame
/// (`u64 id | u8 kind | u32 len | payload`) with its length backfilled,
/// and [`WaveEncoder::finish`] backfills the count and the frame length.
/// Everything lands in the caller's (reusable) buffer — the wave path
/// inherits the single-frame zero-copy discipline. One encoder is
/// request-only or response-only, matching how it was begun.
pub struct WaveEncoder {
    len_at: usize,
    count_at: usize,
    count: u32,
}

impl WaveEncoder {
    /// Open a request wave frame (kind 0x20, wire v3).
    pub fn begin_request_wave(out: &mut Vec<u8>) -> WaveEncoder {
        Self::begin(out, KIND_REQ_WAVE)
    }

    /// Open a response wave frame (kind 0xA0, wire v3).
    pub fn begin_response_wave(out: &mut Vec<u8>) -> WaveEncoder {
        Self::begin(out, KIND_RESP_WAVE)
    }

    fn begin(out: &mut Vec<u8>, kind: u8) -> WaveEncoder {
        let len_at = begin_frame(out, WAVE_FRAME_VERSION, kind, 0);
        let count_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes());
        WaveEncoder { len_at, count_at, count: 0 }
    }

    fn push_sub(&mut self, out: &mut Vec<u8>, id: u64, kind: u8) -> usize {
        debug_assert!(
            (self.count as usize) < MAX_WAVE,
            "wave frame exceeds MAX_WAVE sub-frames"
        );
        self.count += 1;
        out.extend_from_slice(&id.to_le_bytes());
        out.push(kind);
        let sub_len_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes());
        sub_len_at
    }

    fn finish_sub(out: &mut Vec<u8>, sub_len_at: usize) {
        let len = out.len() - (sub_len_at + 4);
        out[sub_len_at..sub_len_at + 4]
            .copy_from_slice(&(len as u32).to_le_bytes());
    }

    /// Append one sub-request (only on an encoder begun with
    /// [`WaveEncoder::begin_request_wave`]).
    pub fn push_request(&mut self, out: &mut Vec<u8>, id: u64, req: &Request) {
        let sub_len_at = self.push_sub(out, id, request_kind(req));
        encode_request_payload(out, req);
        Self::finish_sub(out, sub_len_at);
    }

    /// Append one sub-response (only on an encoder begun with
    /// [`WaveEncoder::begin_response_wave`]).
    pub fn push_response(
        &mut self,
        out: &mut Vec<u8>,
        id: u64,
        resp: &Response,
    ) {
        let sub_len_at = self.push_sub(out, id, response_kind(resp));
        encode_response_payload(out, resp);
        Self::finish_sub(out, sub_len_at);
    }

    /// Number of sub-frames pushed so far — callers chunking by payload
    /// size read this to decide when to close one frame and open the
    /// next.
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Backfill the count and frame length, closing the wave frame.
    pub fn finish(self, out: &mut Vec<u8>) {
        out[self.count_at..self.count_at + 4]
            .copy_from_slice(&self.count.to_le_bytes());
        finish_frame(out, self.len_at);
    }
}

/// Encode one request wave frame from `(id, request)` pairs. Panics in
/// debug builds beyond [`MAX_WAVE`] items — senders chunk above that.
pub fn encode_request_wave(out: &mut Vec<u8>, items: &[(u64, &Request)]) {
    let mut w = WaveEncoder::begin_request_wave(out);
    for (id, req) in items {
        w.push_request(out, *id, req);
    }
    w.finish(out);
}

/// Encode one response wave frame from `(id, response)` pairs.
pub fn encode_response_wave(out: &mut Vec<u8>, items: &[(u64, Response)]) {
    let mut w = WaveEncoder::begin_response_wave(out);
    for (id, resp) in items {
        w.push_response(out, *id, resp);
    }
    w.finish(out);
}

/// Write one request frame (allocating convenience; hot paths encode
/// into a reused buffer and write that).
pub fn write_request(
    w: &mut impl Write,
    id: u64,
    req: &Request,
) -> Result<(), ProtocolError> {
    let mut buf = Vec::new();
    encode_request(&mut buf, id, req);
    w.write_all(&buf)?;
    Ok(())
}

/// Write one response frame (allocating convenience; the transport
/// server's writer loop instead encodes into a reused per-connection
/// buffer).
pub fn write_response(
    w: &mut impl Write,
    id: u64,
    resp: &Response,
) -> Result<(), ProtocolError> {
    let mut buf = Vec::new();
    encode_response(&mut buf, id, resp);
    w.write_all(&buf)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked payload reader: every decode failure is a typed
/// [`ProtocolError::Malformed`].
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.pos + n > self.buf.len() {
            return Err(ProtocolError::Malformed("payload shorter than encoded"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtocolError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ProtocolError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>, ProtocolError> {
        let raw = self.take(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn finish(&self) -> Result<(), ProtocolError> {
        if self.pos != self.buf.len() {
            return Err(ProtocolError::Malformed("trailing payload bytes"));
        }
        Ok(())
    }

    fn query(&mut self) -> Result<Vec<f32>, ProtocolError> {
        let dim = self.u32()? as usize;
        // The dim prefix can never describe more floats than the payload
        // holds; reject before allocating.
        if dim * 4 > self.buf.len().saturating_sub(self.pos) {
            return Err(ProtocolError::Malformed("query dim exceeds payload"));
        }
        self.f32s(dim)
    }
}

struct Header {
    version: u8,
    kind: u8,
    id: u64,
    len: usize,
}

/// Read exactly one frame header. `Ok(None)` is a clean EOF at a frame
/// boundary (the peer's shutdown signal); EOF *inside* a header is
/// [`ProtocolError::Truncated`].
fn read_header(r: &mut impl Read) -> Result<Option<Header>, ProtocolError> {
    let mut buf = [0u8; HEADER_LEN];
    let mut filled = 0;
    while filled < HEADER_LEN {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(None)
                } else {
                    Err(ProtocolError::Truncated)
                }
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    if buf[0..2] != MAGIC {
        return Err(ProtocolError::BadMagic([buf[0], buf[1]]));
    }
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&buf[2]) {
        return Err(ProtocolError::UnknownVersion(buf[2]));
    }
    let id = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let len = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(ProtocolError::Oversized { len, max: MAX_PAYLOAD });
    }
    Ok(Some(Header { version: buf[2], kind: buf[3], id, len }))
}

fn read_payload(r: &mut impl Read, len: usize) -> Result<Vec<u8>, ProtocolError> {
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Decode one request's kind-specific payload (a whole single-frame
/// payload, or one wave sub-frame's payload — the encodings are
/// identical). Enforces exact length: trailing bytes are malformed.
fn decode_request_payload(
    kind: u8,
    payload: &[u8],
) -> Result<Request, ProtocolError> {
    let mut c = Cursor::new(payload);
    let req = match kind {
        KIND_REQ_SAMPLE => {
            let h = c.query()?;
            let m = c.u32()?;
            let seed = c.u64()?;
            Request::Sample { h, m, seed }
        }
        KIND_REQ_PROBABILITY => {
            let h = c.query()?;
            let class = c.u32()?;
            Request::Probability { h, class }
        }
        KIND_REQ_TOP_K => {
            let h = c.query()?;
            let k = c.u32()?;
            Request::TopK { h, k }
        }
        KIND_REQ_ADD_CLASSES => {
            let rows = c.u32()? as usize;
            let dim = c.u32()?;
            if dim == 0 {
                return Err(ProtocolError::Malformed(
                    "AddClasses: zero embedding dim",
                ));
            }
            // Reject before allocating: the claimed rows×dim may not
            // describe more floats than the payload holds. u64 math for
            // the product (u32×u32 always fits) and checked_mul for the
            // byte count, which a hostile 2^31×2^31 claim WOULD wrap.
            let floats = rows as u64 * dim as u64;
            let byte_len = floats.checked_mul(4).ok_or(
                ProtocolError::Malformed("AddClasses: rows×dim overflows"),
            )?;
            if byte_len > payload.len().saturating_sub(c.pos) as u64 {
                return Err(ProtocolError::Malformed(
                    "AddClasses: rows×dim exceeds payload",
                ));
            }
            let embeddings = c.f32s(floats as usize)?;
            Request::AddClasses { dim, embeddings }
        }
        KIND_REQ_RETIRE_CLASSES => {
            let count = c.u32()? as usize;
            if count * 4 > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed(
                    "RetireClasses: count exceeds payload",
                ));
            }
            let mut ids = Vec::with_capacity(count);
            for _ in 0..count {
                ids.push(c.u32()?);
            }
            Request::RetireClasses { ids }
        }
        // Empty payload; `c.finish()` below rejects any stray bytes, so
        // a malformed (non-empty) STATS request cannot smuggle data.
        KIND_REQ_STATS => Request::Stats,
        KIND_REQ_MASS => {
            let h = c.query()?;
            Request::Mass { h }
        }
        KIND_REQ_SNAPSHOT => {
            let max_chunk = c.u32()?;
            Request::SnapshotFetch { max_chunk }
        }
        other => return Err(ProtocolError::UnknownKind(other)),
    };
    c.finish()?;
    Ok(req)
}

/// Decode one response's kind-specific payload (single-frame or wave
/// sub-frame — identical encodings, exact length enforced).
fn decode_response_payload(
    kind: u8,
    payload: &[u8],
) -> Result<Response, ProtocolError> {
    let mut c = Cursor::new(payload);
    let resp = match kind {
        KIND_RESP_SAMPLE => {
            let epoch = c.u64()?;
            let count = c.u32()? as usize;
            if count * 12 > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed("draw count exceeds payload"));
            }
            let mut ids = Vec::with_capacity(count);
            for _ in 0..count {
                ids.push(c.u32()?);
            }
            let mut probs = Vec::with_capacity(count);
            for _ in 0..count {
                probs.push(c.f64()?);
            }
            Response::Sample { epoch, ids, probs }
        }
        KIND_RESP_PROBABILITY => {
            let epoch = c.u64()?;
            let q = c.f64()?;
            Response::Probability { epoch, q }
        }
        KIND_RESP_TOP_K => {
            let epoch = c.u64()?;
            let count = c.u32()? as usize;
            if count * 12 > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed("item count exceeds payload"));
            }
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                let i = c.u32()?;
                let q = c.f64()?;
                items.push((i, q));
            }
            Response::TopK { epoch, items }
        }
        KIND_RESP_ADD_CLASSES => {
            let epoch = c.u64()?;
            let count = c.u32()? as usize;
            if count * 4 > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed(
                    "AddClasses ack: count exceeds payload",
                ));
            }
            let mut ids = Vec::with_capacity(count);
            for _ in 0..count {
                ids.push(c.u32()?);
            }
            Response::AddClasses { epoch, ids }
        }
        KIND_RESP_RETIRE_CLASSES => {
            let epoch = c.u64()?;
            let count = c.u32()?;
            Response::RetireClasses { epoch, count }
        }
        KIND_RESP_MASS => {
            let epoch = c.u64()?;
            let mass = c.f64()?;
            Response::Mass { epoch, mass }
        }
        KIND_RESP_STATS => {
            let len = c.u32()? as usize;
            // Reject before allocating: the length prefix may not claim
            // more bytes than the payload delivers.
            if len > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed(
                    "stats length exceeds payload",
                ));
            }
            let raw = c.take(len)?;
            let json = String::from_utf8(raw.to_vec()).map_err(|_| {
                ProtocolError::Malformed("stats payload is not utf-8")
            })?;
            Response::Stats { json }
        }
        KIND_RESP_SNAPSHOT => {
            let epoch = c.u64()?;
            let total = c.u64()?;
            let offset = c.u64()?;
            let len = c.u32()? as usize;
            // Reject before allocating: the length prefix may not claim
            // more bytes than the payload delivers, and a chunk may not
            // claim to extend past the stream's total.
            if len > payload.len().saturating_sub(c.pos) {
                return Err(ProtocolError::Malformed(
                    "snapshot chunk length exceeds payload",
                ));
            }
            if offset.checked_add(len as u64).is_none_or(|end| end > total) {
                return Err(ProtocolError::Malformed(
                    "snapshot chunk extends past total",
                ));
            }
            let data = c.take(len)?.to_vec();
            Response::SnapshotChunk { epoch, total, offset, data }
        }
        KIND_RESP_ERROR => {
            let code = c.u8()?;
            let len = c.u16()? as usize;
            let raw = c.take(len)?;
            let message = String::from_utf8_lossy(raw).into_owned();
            Response::Error { code, message }
        }
        other => return Err(ProtocolError::UnknownKind(other)),
    };
    c.finish()?;
    Ok(resp)
}

/// Decode a wave payload into `(id, item)` pairs via the given per-kind
/// payload decoder. The count prefix is validated against [`MAX_WAVE`]
/// and against the delivered bytes *before* the item vector is
/// allocated, so a hostile count cannot balloon memory; nested waves
/// are structurally malformed.
fn decode_wave<T>(
    payload: &[u8],
    decode: impl Fn(u8, &[u8]) -> Result<T, ProtocolError>,
) -> Result<Vec<(u64, T)>, ProtocolError> {
    let mut c = Cursor::new(payload);
    let count = c.u32()? as usize;
    if count == 0 {
        return Err(ProtocolError::Malformed("empty wave frame"));
    }
    if count > MAX_WAVE {
        return Err(ProtocolError::Malformed("wave count exceeds MAX_WAVE"));
    }
    if count * WAVE_SUB_PREFIX > payload.len().saturating_sub(c.pos) {
        return Err(ProtocolError::Malformed("wave count exceeds payload"));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let id = c.u64()?;
        let kind = c.u8()?;
        if kind == KIND_REQ_WAVE || kind == KIND_RESP_WAVE {
            return Err(ProtocolError::Malformed("nested wave frame"));
        }
        let len = c.u32()? as usize;
        let sub = c.take(len)?;
        out.push((id, decode(kind, sub)?));
    }
    c.finish()?;
    Ok(out)
}

/// One decoded request-direction frame: a single request, or a batched
/// wave of them (wire v3).
#[derive(Debug)]
pub enum RequestFrame {
    Single(u64, Request),
    Wave(Vec<(u64, Request)>),
}

/// One decoded response-direction frame.
#[derive(Debug)]
pub enum ResponseFrame {
    Single(u64, Response),
    Wave(Vec<(u64, Response)>),
}

/// Whether a frame kind only exists from wire v3 on. Stamped v2, such
/// a kind decodes to [`ProtocolError::UnknownKind`] — the identical
/// refusal a genuine v2 peer (which predates the kind) would produce.
fn kind_requires_v3(kind: u8) -> bool {
    matches!(
        kind,
        KIND_REQ_STATS
            | KIND_RESP_STATS
            | KIND_REQ_MASS
            | KIND_RESP_MASS
            | KIND_REQ_SNAPSHOT
            | KIND_RESP_SNAPSHOT
    )
}

/// Read one request-direction frame — single or wave — (server side).
/// `Ok(None)` on clean EOF at a frame boundary.
pub fn read_request_frame(
    r: &mut impl Read,
) -> Result<Option<RequestFrame>, ProtocolError> {
    Ok(read_request_frame_traced(r)?.map(|(frame, _)| frame))
}

/// [`read_request_frame`] plus the frame's decode cost in nanoseconds:
/// CPU spent parsing the payload only — the blocking socket reads
/// (header + payload bytes) are excluded, so the serving `decode` stage
/// histogram measures codec work, never peer think-time or network
/// wait.
pub fn read_request_frame_traced(
    r: &mut impl Read,
) -> Result<Option<(RequestFrame, u64)>, ProtocolError> {
    let Some(head) = read_header(r)? else {
        return Ok(None);
    };
    let payload = read_payload(r, head.len)?;
    let t0 = std::time::Instant::now();
    if head.kind == KIND_REQ_WAVE {
        if head.version < WAVE_FRAME_VERSION {
            return Err(ProtocolError::Malformed(
                "wave frame requires wire v3",
            ));
        }
        let subs = decode_wave(&payload, decode_request_payload)?;
        let decode_ns = t0.elapsed().as_nanos() as u64;
        return Ok(Some((RequestFrame::Wave(subs), decode_ns)));
    }
    if head.version < STATS_FRAME_VERSION && kind_requires_v3(head.kind) {
        return Err(ProtocolError::UnknownKind(head.kind));
    }
    let req = decode_request_payload(head.kind, &payload)?;
    let decode_ns = t0.elapsed().as_nanos() as u64;
    Ok(Some((RequestFrame::Single(head.id, req), decode_ns)))
}

/// Read one single-request frame (legacy/single-frame contexts; waves
/// are a framing violation here — servers use [`read_request_frame`]).
pub fn read_request(
    r: &mut impl Read,
) -> Result<Option<(u64, Request)>, ProtocolError> {
    match read_request_frame(r)? {
        None => Ok(None),
        Some(RequestFrame::Single(id, req)) => Ok(Some((id, req))),
        Some(RequestFrame::Wave(_)) => Err(ProtocolError::Malformed(
            "unexpected wave frame (single-frame reader)",
        )),
    }
}

/// Read one response-direction frame — single or wave — (client side).
/// `Ok(None)` on clean EOF at a frame boundary.
pub fn read_response_frame(
    r: &mut impl Read,
) -> Result<Option<ResponseFrame>, ProtocolError> {
    let Some(head) = read_header(r)? else {
        return Ok(None);
    };
    let payload = read_payload(r, head.len)?;
    if head.kind == KIND_RESP_WAVE {
        if head.version < WAVE_FRAME_VERSION {
            return Err(ProtocolError::Malformed(
                "wave frame requires wire v3",
            ));
        }
        let subs = decode_wave(&payload, decode_response_payload)?;
        return Ok(Some(ResponseFrame::Wave(subs)));
    }
    if head.version < STATS_FRAME_VERSION && kind_requires_v3(head.kind) {
        return Err(ProtocolError::UnknownKind(head.kind));
    }
    let resp = decode_response_payload(head.kind, &payload)?;
    Ok(Some(ResponseFrame::Single(head.id, resp)))
}

/// Read one single-response frame (sync clients and tests; wave-capable
/// clients use [`read_response_frame`]).
pub fn read_response(
    r: &mut impl Read,
) -> Result<Option<(u64, Response)>, ProtocolError> {
    match read_response_frame(r)? {
        None => Ok(None),
        Some(ResponseFrame::Single(id, resp)) => Ok(Some((id, resp))),
        Some(ResponseFrame::Wave(_)) => Err(ProtocolError::Malformed(
            "unexpected wave frame (single-frame reader)",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: Request) -> (u64, Request) {
        let mut buf = Vec::new();
        encode_request(&mut buf, 42, &req);
        read_request(&mut &buf[..]).unwrap().unwrap()
    }

    fn round_trip_response(resp: Response) -> (u64, Response) {
        let mut buf = Vec::new();
        encode_response(&mut buf, 7, &resp);
        read_response(&mut &buf[..]).unwrap().unwrap()
    }

    #[test]
    fn request_frames_round_trip_all_kinds() {
        let h = vec![0.25f32, -1.5, 3.0];
        for req in [
            Request::Sample { h: h.clone(), m: 20, seed: 0xDEAD_BEEF },
            Request::Probability { h: h.clone(), class: 17 },
            Request::TopK { h: h.clone(), k: 5 },
        ] {
            let (id, got) = round_trip_request(req.clone());
            assert_eq!(id, 42);
            assert_eq!(got, req);
        }
        // Empty query embeddings survive too.
        let (_, got) =
            round_trip_request(Request::Sample { h: vec![], m: 1, seed: 0 });
        assert_eq!(got, Request::Sample { h: vec![], m: 1, seed: 0 });
    }

    #[test]
    fn response_frames_round_trip_all_kinds() {
        for resp in [
            Response::Sample {
                epoch: 3,
                ids: vec![1, 2, 9],
                probs: vec![0.5, 0.25, 1e-9],
            },
            Response::Probability { epoch: 0, q: 0.125 },
            Response::TopK { epoch: 8, items: vec![(4, 0.5), (0, 0.1)] },
            Response::Error { code: ERR_SERVE, message: "nope".into() },
        ] {
            let (id, got) = round_trip_response(resp.clone());
            assert_eq!(id, 7);
            assert_eq!(got, resp);
        }
    }

    #[test]
    fn admin_frames_round_trip() {
        let req = Request::AddClasses {
            dim: 3,
            embeddings: vec![0.1, 0.2, 0.3, -1.0, 2.0, 0.5],
        };
        let (id, got) = round_trip_request(req.clone());
        assert_eq!(id, 42);
        assert_eq!(got, req);
        assert!(got.is_admin());
        let req = Request::RetireClasses { ids: vec![7, 9, 1000] };
        let (_, got) = round_trip_request(req.clone());
        assert_eq!(got, req);
        assert!(got.is_admin());
        assert!(!Request::TopK { h: vec![], k: 1 }.is_admin());

        for resp in [
            Response::AddClasses { epoch: 5, ids: vec![100, 101] },
            Response::RetireClasses { epoch: 6, count: 3 },
        ] {
            let (_, got) = round_trip_response(resp.clone());
            assert_eq!(got, resp);
        }
    }

    #[test]
    fn malformed_admin_frames_are_rejected() {
        // rows×dim prefix describing more floats than delivered.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x10, 1);
        buf.extend_from_slice(&1000u32.to_le_bytes()); // rows
        buf.extend_from_slice(&1000u32.to_le_bytes()); // dim
        buf.extend_from_slice(&0.5f32.to_le_bytes()); // one float
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // A hostile rows×dim whose BYTE count wraps u64 (2^31 × 2^31 ×
        // 4 ≡ 0 mod 2^64) must be rejected by the checked multiply, not
        // decoded as an empty embedding batch.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x10, 1);
        buf.extend_from_slice(&0x8000_0000u32.to_le_bytes()); // rows
        buf.extend_from_slice(&0x8000_0000u32.to_le_bytes()); // dim
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Zero dim is structurally invalid.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x10, 1);
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Retire count exceeding the payload.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x11, 1);
        buf.extend_from_slice(&50u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // one id only
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Trailing garbage after a valid retire body.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x11, 1);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.push(0xEE);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    // -----------------------------------------------------------------
    // STATS admin frames (wire v3)
    // -----------------------------------------------------------------

    #[test]
    fn stats_frames_round_trip_and_carry_v3() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 9, &Request::Stats);
        assert_eq!(buf[2], 3, "STATS frames must carry wire v3");
        let (id, got) = read_request(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(id, 9);
        assert_eq!(got, Request::Stats);
        assert!(got.is_admin());

        let resp = Response::Stats {
            json: r#"{"stages":{"decode":{"count":3}}}"#.into(),
        };
        let mut buf = Vec::new();
        encode_response(&mut buf, 9, &resp);
        assert_eq!(buf[2], 3);
        let (_, got) = read_response(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn v2_stamped_stats_gets_the_unknown_kind_refusal() {
        // A v2 peer predates the STATS kind, so it would refuse it as
        // unknown; this build must answer a v2-stamped STATS frame with
        // the exact same refusal rather than serving it.
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, &Request::Stats);
        buf[2] = 2;
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x12)
        ));
        let mut buf = Vec::new();
        encode_response(&mut buf, 1, &Response::Stats { json: "{}".into() });
        buf[2] = 2;
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x92)
        ));
    }

    #[test]
    fn malformed_stats_payloads_are_rejected() {
        // STATS requests are empty; any payload bytes are malformed.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x12, 1);
        buf.extend_from_slice(b"junk");
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Response length prefix claiming more bytes than delivered —
        // rejected before any allocation.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x92, 1);
        buf.extend_from_slice(&1_000_000u32.to_le_bytes());
        buf.extend_from_slice(b"{}");
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Length prefix smaller than the delivered body: trailing bytes.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x92, 1);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(b"{}");
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Invalid utf-8 in the snapshot body.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x92, 1);
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0xFF, 0xFE]);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    // -----------------------------------------------------------------
    // STATE_SNAPSHOT admin frames (wire v3)
    // -----------------------------------------------------------------

    #[test]
    fn snapshot_frames_round_trip_and_carry_v3() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 11, &Request::SnapshotFetch { max_chunk: 0 });
        assert_eq!(buf[2], 3, "SNAPSHOT frames must carry wire v3");
        let (id, got) = read_request(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(id, 11);
        assert_eq!(got, Request::SnapshotFetch { max_chunk: 0 });
        assert!(got.is_admin());

        // A middle chunk and a final empty-tail boundary chunk.
        for resp in [
            Response::SnapshotChunk {
                epoch: 4,
                total: 100,
                offset: 32,
                data: vec![0xAB; 48],
            },
            Response::SnapshotChunk {
                epoch: 4,
                total: 100,
                offset: 96,
                data: vec![1, 2, 3, 4],
            },
            Response::SnapshotChunk {
                epoch: 0,
                total: 0,
                offset: 0,
                data: vec![],
            },
        ] {
            let mut buf = Vec::new();
            encode_response(&mut buf, 11, &resp);
            assert_eq!(buf[2], 3);
            let (_, got) = read_response(&mut &buf[..]).unwrap().unwrap();
            assert_eq!(got, resp);
        }
    }

    #[test]
    fn v2_stamped_snapshot_gets_the_unknown_kind_refusal() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, &Request::SnapshotFetch { max_chunk: 64 });
        buf[2] = 2;
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x13)
        ));
        let mut buf = Vec::new();
        encode_response(
            &mut buf,
            1,
            &Response::SnapshotChunk {
                epoch: 0,
                total: 1,
                offset: 0,
                data: vec![9],
            },
        );
        buf[2] = 2;
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x93)
        ));
    }

    #[test]
    fn malformed_snapshot_chunks_are_rejected() {
        // Chunk length prefix claiming more bytes than delivered —
        // rejected before any allocation.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x93, 1);
        buf.extend_from_slice(&0u64.to_le_bytes()); // epoch
        buf.extend_from_slice(&10u64.to_le_bytes()); // total
        buf.extend_from_slice(&0u64.to_le_bytes()); // offset
        buf.extend_from_slice(&1_000_000u32.to_le_bytes()); // len
        buf.push(0x01);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // offset + len past total: a torn stream must not assemble.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x93, 1);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&4u64.to_le_bytes()); // total 4
        buf.extend_from_slice(&3u64.to_le_bytes()); // offset 3
        buf.extend_from_slice(&2u32.to_le_bytes()); // len 2 ⇒ end 5 > 4
        buf.extend_from_slice(&[7, 8]);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // u64 offset overflow in offset+len must be caught, not wrapped.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x93, 1);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[7, 8]);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Trailing bytes after a valid chunk body.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x93, 1);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(0x01);
        buf.push(0xEE);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // SnapshotFetch with a short payload is malformed.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 3, 0x13, 1);
        buf.extend_from_slice(&[0u8; 2]);
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    // -----------------------------------------------------------------
    // MASS frames (wire v3) + timeout classification
    // -----------------------------------------------------------------

    #[test]
    fn mass_frames_round_trip_and_carry_v3() {
        let req = Request::Mass { h: vec![0.5f32, -2.0, 1.25] };
        let mut buf = Vec::new();
        encode_request(&mut buf, 11, &req);
        assert_eq!(buf[2], 3, "MASS frames must carry wire v3");
        let (id, got) = read_request(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(id, 11);
        assert_eq!(got, req);
        assert!(got.is_admin(), "Mass is answered inline, never batched");

        let resp = Response::Mass { epoch: 9, mass: 1234.5 };
        let mut buf = Vec::new();
        encode_response(&mut buf, 11, &resp);
        assert_eq!(buf[2], 3);
        let (_, got) = read_response(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn v2_stamped_mass_gets_the_unknown_kind_refusal() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, &Request::Mass { h: vec![1.0] });
        buf[2] = 2;
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x04)
        ));
        let mut buf = Vec::new();
        encode_response(&mut buf, 1, &Response::Mass { epoch: 0, mass: 1.0 });
        buf[2] = 2;
        assert!(matches!(
            read_response(&mut &buf[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x84)
        ));
    }

    #[test]
    fn socket_deadline_errors_map_to_typed_timeout() {
        for kind in [ErrorKind::WouldBlock, ErrorKind::TimedOut] {
            let err: ProtocolError =
                std::io::Error::new(kind, "deadline").into();
            assert!(matches!(err, ProtocolError::Timeout), "{err}");
            // A timed-out read may have consumed a partial frame, so the
            // connection is unusable afterwards.
            assert!(err.closes_connection());
        }
        let err: ProtocolError =
            std::io::Error::new(ErrorKind::UnexpectedEof, "eof").into();
        assert!(matches!(err, ProtocolError::Truncated));
    }

    #[test]
    fn traced_request_reader_reports_decode_cost() {
        let mut buf = Vec::new();
        encode_request(
            &mut buf,
            4,
            &Request::Sample { h: vec![0.5; 64], m: 8, seed: 3 },
        );
        let (frame, _decode_ns) = super::read_request_frame_traced(&mut &buf[..])
            .unwrap()
            .unwrap();
        assert!(matches!(frame, RequestFrame::Single(4, Request::Sample { .. })));
        // Clean EOF still maps to None.
        assert!(super::read_request_frame_traced(&mut &buf[..0])
            .unwrap()
            .is_none());
    }

    #[test]
    fn overload_error_keeps_connection_usable() {
        assert!(!ProtocolError::Remote {
            code: ERR_OVERLOAD,
            message: String::new()
        }
        .closes_connection());
        let (_, got) = round_trip_response(Response::Error {
            code: ERR_OVERLOAD,
            message: "in-flight cap".into(),
        });
        assert_eq!(
            got,
            Response::Error { code: ERR_OVERLOAD, message: "in-flight cap".into() }
        );
    }

    #[test]
    fn reused_buffer_encode_matches_fresh_encode() {
        // The zero-copy path (header first, length backfilled) must be
        // byte-identical to a fresh single-frame encode, including when
        // frames accumulate in one buffer.
        let reqs = [
            Request::Sample { h: vec![1.0, -2.0], m: 9, seed: 77 },
            Request::RetireClasses { ids: vec![1, 2, 3] },
            Request::TopK { h: vec![0.5; 7], k: 4 },
        ];
        let mut joint = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            encode_request(&mut joint, i as u64, r);
        }
        let mut cursor = &joint[..];
        for (i, r) in reqs.iter().enumerate() {
            let (id, got) = read_request(&mut cursor).unwrap().unwrap();
            assert_eq!(id, i as u64);
            assert_eq!(&got, r);
        }
        assert!(read_request(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn truncated_header_and_payload_are_typed_errors() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, &Request::TopK { h: vec![1.0], k: 3 });
        // Cut inside the header…
        let err = read_request(&mut &buf[..HEADER_LEN - 4]).unwrap_err();
        assert!(matches!(err, ProtocolError::Truncated), "{err}");
        // …and inside the payload.
        let err = read_request(&mut &buf[..buf.len() - 2]).unwrap_err();
        assert!(matches!(err, ProtocolError::Truncated), "{err}");
        // Clean EOF at a frame boundary is NOT an error.
        assert!(read_request(&mut &buf[..0]).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(WIRE_VERSION);
        buf.push(0x01);
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        let err = read_request(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, ProtocolError::Oversized { .. }), "{err}");
    }

    #[test]
    fn unknown_version_magic_and_kind_are_typed_errors() {
        let mut ok = Vec::new();
        encode_request(&mut ok, 1, &Request::TopK { h: vec![1.0], k: 3 });

        let mut bad_version = ok.clone();
        bad_version[2] = 99;
        assert!(matches!(
            read_request(&mut &bad_version[..]).unwrap_err(),
            ProtocolError::UnknownVersion(99)
        ));

        let mut bad_magic = ok.clone();
        bad_magic[0] = b'X';
        assert!(matches!(
            read_request(&mut &bad_magic[..]).unwrap_err(),
            ProtocolError::BadMagic(_)
        ));

        let mut bad_kind = ok.clone();
        bad_kind[3] = 0x77;
        assert!(matches!(
            read_request(&mut &bad_kind[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x77)
        ));
        // A response kind arriving where requests are expected is equally
        // a violation.
        let mut resp_at_server = ok;
        resp_at_server[3] = 0x81;
        assert!(matches!(
            read_request(&mut &resp_at_server[..]).unwrap_err(),
            ProtocolError::UnknownKind(0x81)
        ));
    }

    #[test]
    fn malformed_payloads_are_rejected() {
        // Query dim prefix larger than the actual payload.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x03, 1);
        buf.extend_from_slice(&1000u32.to_le_bytes()); // claims 1000 floats
        buf.extend_from_slice(&0.5f32.to_le_bytes()); // …delivers one
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Trailing garbage after a valid body.
        let mut buf = Vec::new();
        let len_at = super::begin_frame(&mut buf, 2, 0x03, 1);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&0.5f32.to_le_bytes());
        buf.extend_from_slice(&3u32.to_le_bytes()); // k
        buf.push(0xAB); // trailing byte
        super::finish_frame(&mut buf, len_at);
        assert!(matches!(
            read_request(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    #[test]
    fn error_classification_for_connection_teardown() {
        assert!(ProtocolError::Truncated.closes_connection());
        assert!(ProtocolError::UnknownVersion(9).closes_connection());
        assert!(ProtocolError::Remote { code: ERR_PROTOCOL, message: String::new() }
            .closes_connection());
        assert!(!ProtocolError::Remote { code: ERR_SERVE, message: String::new() }
            .closes_connection());
    }

    #[test]
    fn request_into_query_maps_kinds() {
        let (h, q) =
            Request::Sample { h: vec![1.0], m: 9, seed: 4 }.into_query();
        assert_eq!(h, vec![1.0]);
        assert_eq!(q, ServeQuery::Sample { m: 9, seed: 4 });
        let (_, q) = Request::Probability { h: vec![], class: 3 }.into_query();
        assert_eq!(q, ServeQuery::Probability { class: 3 });
        let (_, q) = Request::TopK { h: vec![], k: 2 }.into_query();
        assert_eq!(q, ServeQuery::TopK { k: 2 });
    }

    // -----------------------------------------------------------------
    // Wire v3: batched wave frames
    // -----------------------------------------------------------------

    fn mixed_requests() -> Vec<Request> {
        vec![
            Request::Sample { h: vec![0.5, -1.0], m: 4, seed: 11 },
            Request::Probability { h: vec![2.0, 0.0], class: 7 },
            Request::TopK { h: vec![1.0; 3], k: 2 },
            Request::RetireClasses { ids: vec![3, 9] },
        ]
    }

    #[test]
    fn request_wave_round_trips_with_sub_ids_preserved() {
        let reqs = mixed_requests();
        let items: Vec<(u64, &Request)> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| (100 + i as u64, r))
            .collect();
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        // One header for the whole burst, carrying wire v3.
        assert_eq!(buf[2], 3, "wave frames must carry wire v3");
        let frame = read_request_frame(&mut &buf[..]).unwrap().unwrap();
        let RequestFrame::Wave(subs) = frame else {
            panic!("expected wave frame")
        };
        assert_eq!(subs.len(), reqs.len());
        for (i, (id, got)) in subs.iter().enumerate() {
            assert_eq!(*id, 100 + i as u64, "sub-request id not preserved");
            assert_eq!(got, &reqs[i]);
        }
    }

    #[test]
    fn response_wave_round_trips_including_error_subs() {
        // Partial failure: an Error sub-response rides in its slot
        // without poisoning the rest of the wave.
        let items = vec![
            (
                7u64,
                Response::Sample { epoch: 2, ids: vec![1], probs: vec![0.5] },
            ),
            (
                8u64,
                Response::Error { code: ERR_SERVE, message: "bad dim".into() },
            ),
            (9u64, Response::TopK { epoch: 2, items: vec![(3, 0.25)] }),
        ];
        let mut buf = Vec::new();
        encode_response_wave(&mut buf, &items);
        let frame = read_response_frame(&mut &buf[..]).unwrap().unwrap();
        let ResponseFrame::Wave(subs) = frame else {
            panic!("expected wave frame")
        };
        assert_eq!(subs.len(), 3);
        for ((want_id, want), (id, got)) in items.iter().zip(&subs) {
            assert_eq!(want_id, id);
            assert_eq!(want, got);
        }
    }

    #[test]
    fn single_frames_keep_encoding_v2_for_interop() {
        // v2 peers must keep understanding everything except waves, so
        // singles pin version 2 on the wire even in a v3 build...
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, &Request::TopK { h: vec![1.0], k: 3 });
        assert_eq!(buf[2], 2, "single frames must stay at wire v2");
        let mut buf = Vec::new();
        encode_response(&mut buf, 1, &Response::Probability { epoch: 0, q: 0.5 });
        assert_eq!(buf[2], 2);
        // ...and this build accepts both versions on the way in: the
        // same frame bytes decode whether stamped v2 or v3.
        let mut v3 = Vec::new();
        encode_request(&mut v3, 1, &Request::TopK { h: vec![1.0], k: 3 });
        v3[2] = 3;
        assert!(read_request(&mut &v3[..]).unwrap().is_some());
    }

    #[test]
    fn wave_frame_with_v2_header_is_malformed() {
        let reqs = mixed_requests();
        let items: Vec<(u64, &Request)> =
            reqs.iter().map(|r| (1u64, r)).collect();
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        buf[2] = 2; // a v2 peer could never have produced this kind
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
        // And a single-frame reader refuses waves outright.
        let mut ok = Vec::new();
        encode_request_wave(&mut ok, &items);
        assert!(matches!(
            read_request(&mut &ok[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    #[test]
    fn malformed_wave_counts_are_rejected_before_allocation() {
        let patch_count = |buf: &mut Vec<u8>, count: u32| {
            buf[HEADER_LEN..HEADER_LEN + 4]
                .copy_from_slice(&count.to_le_bytes());
        };
        let reqs = mixed_requests();
        let items: Vec<(u64, &Request)> =
            reqs.iter().map(|r| (1u64, r)).collect();

        // Count prefix claiming more sub-frames than the payload holds.
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        patch_count(&mut buf, 50_000);
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Count beyond MAX_WAVE even if the payload were big enough.
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        patch_count(&mut buf, MAX_WAVE as u32 + 1);
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Zero-count waves are structurally invalid.
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        patch_count(&mut buf, 0);
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // Count prefix smaller than the delivered sub-frames: trailing
        // bytes after the last counted sub are malformed.
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items);
        patch_count(&mut buf, items.len() as u32 - 1);
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // A sub-frame length prefix overrunning the wave payload.
        let mut buf = Vec::new();
        encode_request_wave(&mut buf, &items[..1]);
        let sub_len_at = HEADER_LEN + 4 + 8 + 1;
        buf[sub_len_at..sub_len_at + 4]
            .copy_from_slice(&10_000u32.to_le_bytes());
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));

        // A nested wave kind inside a wave.
        let mut buf = Vec::new();
        let mut w = WaveEncoder::begin_request_wave(&mut buf);
        let sub_at = {
            w.count += 1;
            buf.extend_from_slice(&1u64.to_le_bytes());
            buf.push(0x20); // nested wave kind
            let at = buf.len();
            buf.extend_from_slice(&0u32.to_le_bytes());
            at
        };
        WaveEncoder::finish_sub(&mut buf, sub_at);
        w.finish(&mut buf);
        assert!(matches!(
            read_request_frame(&mut &buf[..]).unwrap_err(),
            ProtocolError::Malformed(_)
        ));
    }

    #[test]
    fn wave_sub_payloads_match_single_frame_payloads() {
        // The per-kind payload encoding is shared between singles and
        // wave subs; a decoded sub must equal the single-frame decode of
        // the same request.
        for req in mixed_requests() {
            let mut single = Vec::new();
            encode_request(&mut single, 5, &req);
            let (_, from_single) =
                read_request(&mut &single[..]).unwrap().unwrap();
            let mut wave = Vec::new();
            encode_request_wave(&mut wave, &[(5, &req)]);
            let RequestFrame::Wave(subs) =
                read_request_frame(&mut &wave[..]).unwrap().unwrap()
            else {
                panic!("expected wave")
            };
            assert_eq!(subs[0].1, from_single);
        }
    }

    #[test]
    fn incremental_wave_encoder_matches_slice_encoder() {
        let reqs = mixed_requests();
        let items: Vec<(u64, &Request)> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| (i as u64, r))
            .collect();
        let mut a = Vec::new();
        encode_request_wave(&mut a, &items);
        let mut b = Vec::new();
        let mut w = WaveEncoder::begin_request_wave(&mut b);
        for (id, r) in &items {
            w.push_request(&mut b, *id, r);
        }
        assert_eq!(w.count(), items.len());
        w.finish(&mut b);
        assert_eq!(a, b);
    }
}
