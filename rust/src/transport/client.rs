//! Client side of the serving transport: sync request/response plus a
//! pipelined mode that keeps many requests in flight on one connection
//! (that is what makes server-side coalescing reachable from a single
//! closed-loop client).

use super::wire::{self, ProtocolError, Request, Response};
use crate::linalg::Matrix;
use crate::sampler::NegativeDraw;
use crate::serving::ServeReply;
use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

/// One connection to a [`super::TransportServer`].
///
/// * **Sync mode** ([`TransportClient::sample`] /
///   [`TransportClient::probability`] / [`TransportClient::top_k`]): one
///   request on the wire at a time, response id checked.
/// * **Pipelined mode** ([`TransportClient::pipeline`]): a whole wave of
///   requests is written before any response is read; responses are
///   matched back to request order by id, so the server may answer out
///   of order.
pub struct TransportClient {
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
    next_id: u64,
    /// Reused encode buffer (zero-copy frame path: one allocation serves
    /// every request this client ever sends).
    encode_buf: Vec<u8>,
}

impl TransportClient {
    /// Connect to a serving socket.
    pub fn connect(path: impl AsRef<Path>) -> std::io::Result<TransportClient> {
        let stream = UnixStream::connect(path)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(TransportClient {
            reader,
            writer,
            next_id: 1,
            encode_buf: Vec::with_capacity(4 * 1024),
        })
    }

    fn send(&mut self, id: u64, req: &Request) -> Result<(), ProtocolError> {
        self.encode_buf.clear();
        wire::encode_request(&mut self.encode_buf, id, req);
        self.writer.write_all(&self.encode_buf)?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<(u64, Response), ProtocolError> {
        match wire::read_response(&mut self.reader)? {
            Some(x) => Ok(x),
            None => Err(ProtocolError::Truncated),
        }
    }

    /// Sync round trip: send one request, read its response, verify the
    /// echoed id. `Error` responses surface as
    /// [`ProtocolError::Remote`].
    fn call(&mut self, req: &Request) -> Result<Response, ProtocolError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(id, req)?;
        let (got_id, resp) = self.recv()?;
        match resp {
            Response::Error { code, message } => {
                Err(ProtocolError::Remote { code, message })
            }
            _ if got_id != id => {
                Err(ProtocolError::IdMismatch { sent: id, got: got_id })
            }
            resp => Ok(resp),
        }
    }

    /// Draw `m` classes from `q(· | h)` under the server's pinned
    /// snapshot; `seed` rides the wire, so the draw is byte-identical to
    /// an in-process `MicroBatcher::sample` with the same seed and
    /// epoch.
    pub fn sample(
        &mut self,
        h: &[f32],
        m: usize,
        seed: u64,
    ) -> Result<ServeReply, ProtocolError> {
        let req = Request::Sample { h: h.to_vec(), m: m as u32, seed };
        match self.call(&req)? {
            Response::Sample { epoch, ids, probs } => {
                Ok(ServeReply { draw: NegativeDraw { ids, probs }, epoch })
            }
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Exact `q(class | h)` plus the epoch it was read from.
    pub fn probability(
        &mut self,
        h: &[f32],
        class: usize,
    ) -> Result<(f64, u64), ProtocolError> {
        let req = Request::Probability { h: h.to_vec(), class: class as u32 };
        match self.call(&req)? {
            Response::Probability { epoch, q } => Ok((q, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Top-k classes under `q(· | h)`, descending, plus the epoch.
    pub fn top_k(
        &mut self,
        h: &[f32],
        k: usize,
    ) -> Result<(Vec<(u32, f64)>, u64), ProtocolError> {
        let req = Request::TopK { h: h.to_vec(), k: k as u32 };
        match self.call(&req)? {
            Response::TopK { epoch, items } => Ok((items, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Grow the served class universe: row `k` of `embeddings` becomes a
    /// new class (admin frame; the server must have been bound with a
    /// [`super::VocabAdmin`] hook). Returns the assigned ids and the
    /// epoch of the snapshot swap that made them visible.
    pub fn add_classes(
        &mut self,
        embeddings: &Matrix,
    ) -> Result<(Vec<u32>, u64), ProtocolError> {
        let req = Request::AddClasses {
            dim: embeddings.cols() as u32,
            embeddings: embeddings.data().to_vec(),
        };
        match self.call(&req)? {
            Response::AddClasses { epoch, ids } => Ok((ids, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Retire live classes from the served universe (admin frame);
    /// returns the epoch of the swap that exposed the holes.
    pub fn retire_classes(
        &mut self,
        ids: &[u32],
    ) -> Result<u64, ProtocolError> {
        let req = Request::RetireClasses { ids: ids.to_vec() };
        match self.call(&req)? {
            Response::RetireClasses { epoch, .. } => Ok(epoch),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Pipelined wave with a **sliding window**: keep up to
    /// `PIPELINE_WINDOW` requests in flight, topping the window up in
    /// buffered chunks and reading responses as they stream back.
    /// Windowing is what makes arbitrarily large waves safe: a client
    /// that blind-writes a whole wave before reading can deadlock
    /// against the server's flow control once both socket buffers fill
    /// (server reader throttled at its outstanding-reply ceiling, server
    /// writer blocked on an unread socket). The window also stays below
    /// the server's per-connection in-flight cap, so a well-behaved
    /// client is never shed.
    ///
    /// Returns responses in *request order* regardless of the order the
    /// server answered in; per-request failures — serve rejections and
    /// [`wire::ERR_OVERLOAD`] backpressure sheds — appear as
    /// [`Response::Error`] entries rather than failing the wave.
    pub fn pipeline(
        &mut self,
        requests: &[Request],
    ) -> Result<Vec<Response>, ProtocolError> {
        /// Max requests awaiting replies — half the server's shed cap,
        /// so coalescing stays deep while overload shedding never
        /// engages for this client.
        const PIPELINE_WINDOW: usize = super::server::MAX_IN_FLIGHT / 2;

        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let base = self.next_id;
        self.next_id += requests.len() as u64;
        let mut out: Vec<Option<Response>> = vec![None; requests.len()];
        let mut sent = 0usize;
        let mut received = 0usize;
        while received < requests.len() {
            // Top the window up in one buffered write whenever it drops
            // to half depth (amortizes write syscalls without ever
            // letting the in-flight count exceed the window).
            let in_flight = sent - received;
            if sent < requests.len() && in_flight <= PIPELINE_WINDOW / 2 {
                let until =
                    requests.len().min(received + PIPELINE_WINDOW);
                self.encode_buf.clear();
                for (i, req) in
                    requests.iter().enumerate().take(until).skip(sent)
                {
                    wire::encode_request(
                        &mut self.encode_buf,
                        base + i as u64,
                        req,
                    );
                }
                self.writer.write_all(&self.encode_buf)?;
                self.writer.flush()?;
                sent = until;
            }
            let (id, resp) = self.recv()?;
            if let Response::Error { code, message } = &resp {
                // Connection-level errors (id 0 / protocol code) fail
                // the whole wave; request-level errors (serve failures,
                // overload sheds) fill their slot.
                if !matches!(*code, wire::ERR_SERVE | wire::ERR_OVERLOAD) {
                    return Err(ProtocolError::Remote {
                        code: *code,
                        message: message.clone(),
                    });
                }
            }
            let slot = id
                .checked_sub(base)
                .map(|o| o as usize)
                .filter(|&o| o < requests.len())
                .ok_or(ProtocolError::IdMismatch { sent: base, got: id })?;
            if out[slot].replace(resp).is_some() {
                return Err(ProtocolError::Malformed("duplicate response id"));
            }
            received += 1;
        }
        Ok(out.into_iter().map(|r| r.expect("filled above")).collect())
    }
}
