//! Client side of the serving transport: sync request/response plus a
//! pipelined mode that keeps many requests in flight on one connection
//! (that is what makes server-side coalescing reachable from a single
//! closed-loop client).

use super::wire::{self, ProtocolError, Request, Response};
use crate::sampler::NegativeDraw;
use crate::serving::ServeReply;
use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

/// One connection to a [`super::TransportServer`].
///
/// * **Sync mode** ([`TransportClient::sample`] /
///   [`TransportClient::probability`] / [`TransportClient::top_k`]): one
///   request on the wire at a time, response id checked.
/// * **Pipelined mode** ([`TransportClient::pipeline`]): a whole wave of
///   requests is written before any response is read; responses are
///   matched back to request order by id, so the server may answer out
///   of order.
pub struct TransportClient {
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
    next_id: u64,
}

impl TransportClient {
    /// Connect to a serving socket.
    pub fn connect(path: impl AsRef<Path>) -> std::io::Result<TransportClient> {
        let stream = UnixStream::connect(path)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(TransportClient { reader, writer, next_id: 1 })
    }

    fn send(&mut self, id: u64, req: &Request) -> Result<(), ProtocolError> {
        wire::write_request(&mut self.writer, id, req)?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<(u64, Response), ProtocolError> {
        match wire::read_response(&mut self.reader)? {
            Some(x) => Ok(x),
            None => Err(ProtocolError::Truncated),
        }
    }

    /// Sync round trip: send one request, read its response, verify the
    /// echoed id. `Error` responses surface as
    /// [`ProtocolError::Remote`].
    fn call(&mut self, req: &Request) -> Result<Response, ProtocolError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(id, req)?;
        let (got_id, resp) = self.recv()?;
        match resp {
            Response::Error { code, message } => {
                Err(ProtocolError::Remote { code, message })
            }
            _ if got_id != id => {
                Err(ProtocolError::IdMismatch { sent: id, got: got_id })
            }
            resp => Ok(resp),
        }
    }

    /// Draw `m` classes from `q(· | h)` under the server's pinned
    /// snapshot; `seed` rides the wire, so the draw is byte-identical to
    /// an in-process `MicroBatcher::sample` with the same seed and
    /// epoch.
    pub fn sample(
        &mut self,
        h: &[f32],
        m: usize,
        seed: u64,
    ) -> Result<ServeReply, ProtocolError> {
        let req = Request::Sample { h: h.to_vec(), m: m as u32, seed };
        match self.call(&req)? {
            Response::Sample { epoch, ids, probs } => {
                Ok(ServeReply { draw: NegativeDraw { ids, probs }, epoch })
            }
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Exact `q(class | h)` plus the epoch it was read from.
    pub fn probability(
        &mut self,
        h: &[f32],
        class: usize,
    ) -> Result<(f64, u64), ProtocolError> {
        let req = Request::Probability { h: h.to_vec(), class: class as u32 };
        match self.call(&req)? {
            Response::Probability { epoch, q } => Ok((q, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Top-k classes under `q(· | h)`, descending, plus the epoch.
    pub fn top_k(
        &mut self,
        h: &[f32],
        k: usize,
    ) -> Result<(Vec<(u32, f64)>, u64), ProtocolError> {
        let req = Request::TopK { h: h.to_vec(), k: k as u32 };
        match self.call(&req)? {
            Response::TopK { epoch, items } => Ok((items, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Pipelined wave: write every request back-to-back (one flush), then
    /// read responses until each request has its answer. Returns
    /// responses in *request order* regardless of the order the server
    /// answered in; per-request failures appear as
    /// [`Response::Error`] entries rather than failing the wave.
    pub fn pipeline(
        &mut self,
        requests: &[Request],
    ) -> Result<Vec<Response>, ProtocolError> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let base = self.next_id;
        self.next_id += requests.len() as u64;
        for (i, req) in requests.iter().enumerate() {
            wire::write_request(&mut self.writer, base + i as u64, req)?;
        }
        self.writer.flush()?;
        let mut out: Vec<Option<Response>> = vec![None; requests.len()];
        let mut pending = requests.len();
        while pending > 0 {
            let (id, resp) = self.recv()?;
            if let Response::Error { code, message } = &resp {
                // Connection-level errors (id 0 / protocol code) fail
                // the whole wave; request-level errors fill their slot.
                if *code != wire::ERR_SERVE {
                    return Err(ProtocolError::Remote {
                        code: *code,
                        message: message.clone(),
                    });
                }
            }
            let slot = id
                .checked_sub(base)
                .map(|o| o as usize)
                .filter(|&o| o < requests.len())
                .ok_or(ProtocolError::IdMismatch { sent: base, got: id })?;
            if out[slot].replace(resp).is_some() {
                return Err(ProtocolError::Malformed("duplicate response id"));
            }
            pending -= 1;
        }
        Ok(out.into_iter().map(|r| r.expect("filled above")).collect())
    }
}
