//! Client side of the serving transport: sync request/response plus a
//! pipelined mode that keeps many requests in flight on one connection
//! (that is what makes server-side coalescing reachable from a single
//! closed-loop client). Transport-agnostic: the same client speaks over
//! a unix socket ([`TransportClient::connect`]) or TCP
//! ([`TransportClient::connect_tcp`], `TCP_NODELAY` set).

use super::net::{Endpoint, Stream};
use super::wire::{self, ProtocolError, Request, Response, ResponseFrame};
use crate::admin::{AdminError, AdminOp, AdminResponse, AdminSurface};
use crate::linalg::Matrix;
use crate::sampler::NegativeDraw;
use crate::serving::ServeReply;
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Write};
use std::net::ToSocketAddrs;
use std::path::Path;

/// Client-side reply-direction frame accounting
/// ([`TransportClient::frame_stats`]): the per-request header overhead
/// is `resp_frames / resp_items` — 1.0 without waves, ≈ `1/wave` with
/// packed replies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientFrameStats {
    /// Frames carrying responses parsed.
    pub resp_frames: u64,
    /// Responses received (wave sub-responses included).
    pub resp_items: u64,
}

/// One connection to a [`super::TransportServer`].
///
/// * **Sync mode** ([`TransportClient::sample`] /
///   [`TransportClient::probability`] / [`TransportClient::top_k`]): one
///   request on the wire at a time, response id checked.
/// * **Pipelined mode** ([`TransportClient::pipeline`] /
///   [`TransportClient::pipeline_waves`]): a whole burst of requests is
///   kept in flight behind a sliding window; responses are matched back
///   to request order by id, so the server may answer out of order.
///   `pipeline_waves` additionally packs the burst into wire v3 **wave
///   frames** — one header per `wave` requests instead of per request —
///   and accepts wave response frames back.
pub struct TransportClient {
    reader: BufReader<Stream>,
    writer: BufWriter<Stream>,
    next_id: u64,
    /// Reused encode buffer (zero-copy frame path: one allocation serves
    /// every request this client ever sends).
    encode_buf: Vec<u8>,
    /// Sub-responses decoded from a wave frame beyond the one the
    /// current `recv_any` caller consumed.
    pending: VecDeque<(u64, Response)>,
    /// Frames carrying responses parsed, and responses received — the
    /// client-side per-request header overhead is
    /// `resp_frames / resp_items` (1.0 without waves, ≈ 1/wave with
    /// packed replies).
    resp_frames: u64,
    resp_items: u64,
}

impl TransportClient {
    /// Connect to a serving unix socket.
    pub fn connect(path: impl AsRef<Path>) -> std::io::Result<TransportClient> {
        Self::from_stream(Stream::connect(&Endpoint::Uds(
            path.as_ref().to_path_buf(),
        ))?)
    }

    /// Connect to a serving TCP address (e.g. `"127.0.0.1:7411"`);
    /// `TCP_NODELAY` is set — frames are written whole, so Nagle could
    /// only add latency.
    pub fn connect_tcp(
        addr: impl ToSocketAddrs,
    ) -> std::io::Result<TransportClient> {
        Self::from_stream(Stream::connect_tcp(addr)?)
    }

    /// Connect to whichever endpoint a server reports
    /// ([`super::TransportServer::endpoint`]).
    pub fn connect_endpoint(
        endpoint: &Endpoint,
    ) -> std::io::Result<TransportClient> {
        Self::from_stream(Stream::connect(endpoint)?)
    }

    /// [`TransportClient::connect_endpoint`] with a connect deadline
    /// *and* a read deadline armed on the resulting connection — a dead
    /// peer fails with a typed [`ProtocolError::Timeout`] instead of
    /// hanging forever. The cluster router's failover path depends on
    /// this. (TCP honors the connect deadline in the kernel; unix-socket
    /// connects cannot hang on a live filesystem, so only the read
    /// deadline applies there.)
    pub fn connect_endpoint_timeout(
        endpoint: &Endpoint,
        timeout: std::time::Duration,
    ) -> std::io::Result<TransportClient> {
        let client =
            Self::from_stream(Stream::connect_timeout(endpoint, timeout)?)?;
        client.set_read_timeout(Some(timeout))?;
        Ok(client)
    }

    /// Arm (or with `None` disarm) a read deadline on this connection.
    /// A read that trips it surfaces as [`ProtocolError::Timeout`] —
    /// which is fatal for the connection (a partial frame may have been
    /// consumed), so callers reconnect rather than retry on the same
    /// stream.
    pub fn set_read_timeout(
        &self,
        timeout: Option<std::time::Duration>,
    ) -> std::io::Result<()> {
        // Reader and writer clone one socket; arming either arms both.
        self.reader.get_ref().set_read_timeout(timeout)
    }

    fn from_stream(stream: Stream) -> std::io::Result<TransportClient> {
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(TransportClient {
            reader,
            writer,
            next_id: 1,
            encode_buf: Vec::with_capacity(4 * 1024),
            pending: VecDeque::new(),
            resp_frames: 0,
            resp_items: 0,
        })
    }

    /// Reply-direction frame accounting as a named snapshot — the
    /// header-amortization observable on the reply direction.
    pub fn frame_stats(&self) -> ClientFrameStats {
        ClientFrameStats {
            resp_frames: self.resp_frames,
            resp_items: self.resp_items,
        }
    }

    fn send(&mut self, id: u64, req: &Request) -> Result<(), ProtocolError> {
        self.encode_buf.clear();
        wire::encode_request(&mut self.encode_buf, id, req);
        self.writer.write_all(&self.encode_buf)?;
        self.writer.flush()?;
        Ok(())
    }

    /// Next `(id, response)`, transparently unpacking wave response
    /// frames (subs beyond the first queue up for subsequent calls).
    fn recv_any(&mut self) -> Result<(u64, Response), ProtocolError> {
        if let Some(x) = self.pending.pop_front() {
            return Ok(x);
        }
        match wire::read_response_frame(&mut self.reader)? {
            None => Err(ProtocolError::Truncated),
            Some(ResponseFrame::Single(id, resp)) => {
                self.resp_frames += 1;
                self.resp_items += 1;
                Ok((id, resp))
            }
            Some(ResponseFrame::Wave(mut subs)) => {
                self.resp_frames += 1;
                self.resp_items += subs.len() as u64;
                // decode_wave rejects empty waves, so there is a first.
                let first = subs.remove(0);
                self.pending.extend(subs);
                Ok(first)
            }
        }
    }

    /// Sync round trip: send one request, read its response, verify the
    /// echoed id. `Error` responses surface as
    /// [`ProtocolError::Remote`].
    fn call(&mut self, req: &Request) -> Result<Response, ProtocolError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(id, req)?;
        let (got_id, resp) = self.recv_any()?;
        match resp {
            Response::Error { code, message } => {
                Err(ProtocolError::Remote { code, message })
            }
            _ if got_id != id => {
                Err(ProtocolError::IdMismatch { sent: id, got: got_id })
            }
            resp => Ok(resp),
        }
    }

    /// Draw `m` classes from `q(· | h)` under the server's pinned
    /// snapshot; `seed` rides the wire, so the draw is byte-identical to
    /// an in-process `MicroBatcher::sample` with the same seed and
    /// epoch.
    pub fn sample(
        &mut self,
        h: &[f32],
        m: usize,
        seed: u64,
    ) -> Result<ServeReply, ProtocolError> {
        let req = Request::Sample { h: h.to_vec(), m: m as u32, seed };
        match self.call(&req)? {
            Response::Sample { epoch, ids, probs } => {
                Ok(ServeReply { draw: NegativeDraw { ids, probs }, epoch })
            }
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Exact `q(class | h)` plus the epoch it was read from.
    pub fn probability(
        &mut self,
        h: &[f32],
        class: usize,
    ) -> Result<(f64, u64), ProtocolError> {
        let req = Request::Probability { h: h.to_vec(), class: class as u32 };
        match self.call(&req)? {
            Response::Probability { epoch, q } => Ok((q, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Top-k classes under `q(· | h)`, descending, plus the epoch.
    pub fn top_k(
        &mut self,
        h: &[f32],
        k: usize,
    ) -> Result<(Vec<(u32, f64)>, u64), ProtocolError> {
        let req = Request::TopK { h: h.to_vec(), k: k as u32 };
        match self.call(&req)? {
            Response::TopK { epoch, items } => Ok((items, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Grow the served class universe: row `k` of `embeddings` becomes a
    /// new class (admin frame; the server must have been bound with a
    /// [`super::VocabAdmin`] hook). Returns the assigned ids and the
    /// epoch of the snapshot swap that made them visible.
    pub fn add_classes(
        &mut self,
        embeddings: &Matrix,
    ) -> Result<(Vec<u32>, u64), ProtocolError> {
        let req = Request::AddClasses {
            dim: embeddings.cols() as u32,
            embeddings: embeddings.data().to_vec(),
        };
        match self.call(&req)? {
            Response::AddClasses { epoch, ids } => Ok((ids, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Scrape the server's live telemetry: one `STATS` admin frame,
    /// answered on every server (no [`super::VocabAdmin`] hook needed).
    /// Returns the raw JSON snapshot text — parse it with
    /// [`crate::json::parse`]. Servers older than wire v3 refuse the
    /// frame with an unknown-kind protocol error.
    pub fn stats(&mut self) -> Result<String, ProtocolError> {
        match self.call(&Request::Stats)? {
            Response::Stats { json } => Ok(json),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Total proposal mass `M(h)` of the server's pinned snapshot at
    /// query `h`, plus the epoch it was read from (wire v3). The
    /// normalizer of the served distribution: `q(i|h) · M(h)` is class
    /// `i`'s unnormalized mass, which is what lets a cluster router
    /// merge draws from disjoint replicas exactly.
    pub fn mass(&mut self, h: &[f32]) -> Result<(f64, u64), ProtocolError> {
        let req = Request::Mass { h: h.to_vec() };
        match self.call(&req)? {
            Response::Mass { epoch, mass } => Ok((mass, epoch)),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Allocate `n` consecutive request ids (router fan-out: sub-request
    /// ids must be unique per connection even though the router, not
    /// this client, tracks them).
    pub(crate) fn alloc_ids(&mut self, n: usize) -> u64 {
        let base = self.next_id;
        self.next_id += n as u64;
        base
    }

    /// Write a batch of pre-id'd requests to this connection in one
    /// buffered flush — as ONE wire v3 wave frame per
    /// [`wire::MAX_WAVE`]/soft-payload chunk when `wave` is set, as
    /// single frames otherwise — without reading anything back. The
    /// cluster router uses this to fan sub-requests out to every replica
    /// *before* collecting replies, so replicas compute in parallel.
    /// Callers keep batches below the server's in-flight cap.
    pub(crate) fn send_batch(
        &mut self,
        items: &[(u64, Request)],
        wave: bool,
    ) -> Result<(), ProtocolError> {
        self.encode_buf.clear();
        if !wave || items.len() == 1 {
            for (id, req) in items {
                wire::encode_request(&mut self.encode_buf, *id, req);
            }
        } else {
            let mut i = 0;
            while i < items.len() {
                let frame_start = self.encode_buf.len();
                let mut enc =
                    wire::WaveEncoder::begin_request_wave(&mut self.encode_buf);
                while i < items.len()
                    && enc.count() < wire::MAX_WAVE
                    && (enc.count() == 0
                        || self.encode_buf.len() - frame_start
                            < wire::WAVE_SOFT_PAYLOAD)
                {
                    enc.push_request(&mut self.encode_buf, items[i].0, &items[i].1);
                    i += 1;
                }
                enc.finish(&mut self.encode_buf);
            }
        }
        self.writer.write_all(&self.encode_buf)?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read the next `(id, response)` off this connection, unpacking
    /// wave frames (router fan-out collection side).
    pub(crate) fn recv_one(&mut self) -> Result<(u64, Response), ProtocolError> {
        self.recv_any()
    }

    /// Retire live classes from the served universe (admin frame);
    /// returns the epoch of the swap that exposed the holes.
    pub fn retire_classes(
        &mut self,
        ids: &[u32],
    ) -> Result<u64, ProtocolError> {
        let req = Request::RetireClasses { ids: ids.to_vec() };
        match self.call(&req)? {
            Response::RetireClasses { epoch, .. } => Ok(epoch),
            _ => Err(ProtocolError::Malformed("response kind mismatch")),
        }
    }

    /// Fetch the server's full durable sampler state as one encoded
    /// snapshot (wire v3 `STATE_SNAPSHOT`; the server must have been
    /// bound with an [`AdminSurface`] hook). The server encodes the
    /// state once under its pinned epoch and streams it back as chunks
    /// sharing this request's id; this reassembles them and returns the
    /// raw [`crate::snapshot::encode`] bytes plus that epoch — decode
    /// with [`crate::snapshot::decode`], or hand the bytes straight to
    /// [`crate::snapshot::write_file`] for a durable copy.
    ///
    /// `max_chunk == 0` accepts the server's default chunk size
    /// ([`wire::MAX_SNAPSHOT_CHUNK`]); smaller values force multi-chunk
    /// streams (tests, tiny-frame transports).
    pub fn fetch_snapshot(
        &mut self,
        max_chunk: u32,
    ) -> Result<(Vec<u8>, u64), ProtocolError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(id, &Request::SnapshotFetch { max_chunk })?;
        let mut bytes: Vec<u8> = Vec::new();
        let mut epoch = 0u64;
        loop {
            let (got_id, resp) = self.recv_any()?;
            match resp {
                Response::Error { code, message } => {
                    return Err(ProtocolError::Remote { code, message });
                }
                _ if got_id != id => {
                    return Err(ProtocolError::IdMismatch {
                        sent: id,
                        got: got_id,
                    });
                }
                Response::SnapshotChunk { epoch: e, total, offset, data } => {
                    if offset != bytes.len() as u64 {
                        return Err(ProtocolError::Malformed(
                            "snapshot chunk out of order",
                        ));
                    }
                    if !bytes.is_empty() && e != epoch {
                        return Err(ProtocolError::Malformed(
                            "snapshot epoch changed mid-stream",
                        ));
                    }
                    epoch = e;
                    bytes.extend_from_slice(&data);
                    if bytes.len() as u64 > total {
                        return Err(ProtocolError::Malformed(
                            "snapshot chunks exceed total",
                        ));
                    }
                    if bytes.len() as u64 == total {
                        return Ok((bytes, epoch));
                    }
                }
                _ => {
                    return Err(ProtocolError::Malformed(
                        "response kind mismatch",
                    ));
                }
            }
        }
    }

    /// Pipelined burst with single-request frames (wire v2 compatible):
    /// [`TransportClient::pipeline_waves`] with a wave size of 1.
    pub fn pipeline(
        &mut self,
        requests: &[Request],
    ) -> Result<Vec<Response>, ProtocolError> {
        self.pipeline_waves(requests, 1)
    }

    /// Pipelined burst with a **wave-gated sliding window**: requests
    /// are packed into wire v3 wave frames of up to `wave` sub-requests
    /// (one header parse per wave at the server instead of per request,
    /// and the whole wave lands in the batcher as one coalesced batch),
    /// while a sliding window keeps the in-flight total below the
    /// server's shed cap. The window advances in *whole waves* — a wave
    /// is written in full or not at all, so it can never be split across
    /// an `ERR_OVERLOAD` boundary, and the server's wave-level cap check
    /// mirrors the same all-or-nothing contract. `wave == 1` degrades to
    /// plain single-request frames (no v3 needed on the peer). Waves
    /// beyond [`wire::MAX_WAVE`] sub-requests or ~1 MiB of encoding are
    /// chunked into consecutive frames.
    ///
    /// Windowing is what makes arbitrarily large bursts safe: a client
    /// that blind-writes everything before reading can deadlock against
    /// the server's flow control once both socket buffers fill (server
    /// reader throttled at its outstanding-reply ceiling, server writer
    /// blocked on an unread socket). The window also stays below the
    /// server's per-connection in-flight cap, so a well-behaved client
    /// is never shed.
    ///
    /// Returns responses in *request order* regardless of the order the
    /// server answered in; per-request failures — serve rejections and
    /// [`wire::ERR_OVERLOAD`] backpressure sheds — appear as
    /// [`Response::Error`] entries rather than failing the burst.
    pub fn pipeline_waves(
        &mut self,
        requests: &[Request],
        wave: usize,
    ) -> Result<Vec<Response>, ProtocolError> {
        /// Max requests awaiting replies — half the server's shed cap,
        /// so coalescing stays deep while overload shedding never
        /// engages for this client.
        const PIPELINE_WINDOW: usize = super::server::MAX_IN_FLIGHT / 2;

        assert!(wave >= 1, "pipeline_waves: wave must be ≥ 1");
        let wave = wave.min(wire::MAX_WAVE);
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let base = self.next_id;
        self.next_id += requests.len() as u64;
        let mut out: Vec<Option<Response>> = vec![None; requests.len()];
        let mut sent = 0usize;
        let mut received = 0usize;
        while received < requests.len() {
            // Top the window up in one buffered write whenever it drops
            // to half depth (amortizes write syscalls without letting
            // the in-flight count exceed the window). The windowing unit
            // is the emitted wire FRAME: every frame leaves this loop
            // either at `in_flight == 0` (the server's wave-level
            // admission takes any single frame whole) or with
            // `in_flight + frame ≤ PIPELINE_WINDOW < MAX_IN_FLIGHT` —
            // so no frame can ever arrive with the shed cap already
            // consumed, even when byte-chunking splits one logical wave
            // across frames. That is what keeps the never-shed /
            // never-split-across-ERR_OVERLOAD contract intact.
            let in_flight = sent - received;
            if sent < requests.len()
                && (in_flight == 0
                    || (in_flight <= PIPELINE_WINDOW / 2
                        && in_flight + wave <= PIPELINE_WINDOW))
            {
                self.encode_buf.clear();
                let mut new_sent = sent;
                while new_sent < requests.len() {
                    let w = wave.min(requests.len() - new_sent);
                    let in_f = new_sent - received;
                    if in_f > 0 && in_f + w > PIPELINE_WINDOW {
                        break;
                    }
                    if w == 1 {
                        wire::encode_request(
                            &mut self.encode_buf,
                            base + new_sent as u64,
                            &requests[new_sent],
                        );
                        new_sent += 1;
                    } else {
                        // ONE wave frame: up to `w` subs, closed early at
                        // the shared soft byte bound so it never nears
                        // MAX_PAYLOAD (whose violation would kill the
                        // connection); the leftover subs go through the
                        // window check again as their own frame.
                        let frame_start = self.encode_buf.len();
                        let mut enc = wire::WaveEncoder::begin_request_wave(
                            &mut self.encode_buf,
                        );
                        while enc.count() < w
                            && (enc.count() == 0
                                || self.encode_buf.len() - frame_start
                                    < wire::WAVE_SOFT_PAYLOAD)
                        {
                            enc.push_request(
                                &mut self.encode_buf,
                                base + new_sent as u64,
                                &requests[new_sent],
                            );
                            new_sent += 1;
                        }
                        enc.finish(&mut self.encode_buf);
                    }
                }
                self.writer.write_all(&self.encode_buf)?;
                self.writer.flush()?;
                sent = new_sent;
            }
            let (id, resp) = self.recv_any()?;
            if let Response::Error { code, message } = &resp {
                // Connection-level errors (id 0 / protocol code) fail
                // the whole burst; request-level errors (serve failures,
                // overload sheds) fill their slot.
                if !matches!(*code, wire::ERR_SERVE | wire::ERR_OVERLOAD) {
                    return Err(ProtocolError::Remote {
                        code: *code,
                        message: message.clone(),
                    });
                }
            }
            let slot = id
                .checked_sub(base)
                .map(|o| o as usize)
                .filter(|&o| o < requests.len())
                .ok_or(ProtocolError::IdMismatch { sent: base, got: id })?;
            if out[slot].replace(resp).is_some() {
                return Err(ProtocolError::Malformed("duplicate response id"));
            }
            received += 1;
        }
        Ok(out.into_iter().map(|r| r.expect("filled above")).collect())
    }
}

/// The wire-forwarding admin surface: the same typed ops that drive a
/// local sampler writer drive a remote server over admin frames, so
/// tooling written against [`AdminSurface`] is transport-agnostic.
/// `Snapshot` fetches and decodes the chunked `STATE_SNAPSHOT` stream.
/// `Restore` is deliberately **not** wire-exposed (a remote caller could
/// otherwise replace a server's entire class universe with one
/// unauthenticated frame); it answers
/// [`AdminError::Unsupported`] — restores happen locally, on the process
/// that owns the writer (CLI `--restore`, cluster bootstrap).
impl AdminSurface for TransportClient {
    fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError> {
        fn lift(e: ProtocolError) -> AdminError {
            match e {
                ProtocolError::Remote { code, message } => {
                    AdminError::Remote { code, message }
                }
                other => AdminError::Transport(other.to_string()),
            }
        }
        match op {
            AdminOp::AddClasses { embeddings } => {
                let (ids, epoch) =
                    self.add_classes(&embeddings).map_err(lift)?;
                Ok(AdminResponse::Added { ids, epoch })
            }
            AdminOp::RetireClasses { ids } => {
                let epoch = self.retire_classes(&ids).map_err(lift)?;
                Ok(AdminResponse::Retired { epoch })
            }
            AdminOp::Snapshot => {
                let (bytes, _epoch) =
                    self.fetch_snapshot(0).map_err(lift)?;
                let snapshot = crate::snapshot::decode(&bytes)?;
                Ok(AdminResponse::Snapshot { snapshot: Box::new(snapshot) })
            }
            AdminOp::Restore { .. } => {
                Err(AdminError::Unsupported("wire admin (restore is local)"))
            }
        }
    }
}
