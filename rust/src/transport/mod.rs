//! L4 — the cross-process serving transport: the serving subsystem
//! (L3.5) behind a real wire, over unix sockets on one machine or TCP
//! across machines.
//!
//! The paper's `O(D log n)` per-draw cost only dominates serving cost at
//! production scale if the plumbing around the tree walks is cheap and
//! shared-work amortization survives the process boundary. This layer
//! supplies both:
//!
//! * [`wire`] — a std-only, length-prefixed, versioned binary protocol:
//!   request/response codecs for `sample`, `probability`, and `top_k`,
//!   with per-request seeds on the wire so served draws stay
//!   deterministic across process boundaries (the same (seed, query,
//!   epoch) yields byte-identical draws in-process, over uds, and over
//!   tcp). Wire v3 adds **batched wave frames**: a pipelined burst packs
//!   into one frame — one header parse and one length check per wave
//!   instead of per request — with sub-request ids preserved and
//!   per-sub-request errors isolated; v2 peers interoperate untouched.
//!   The admin family carries class-universe mutations, the read-only
//!   `STATS` telemetry scrape, and the chunked `STATE_SNAPSHOT` durable
//!   state fetch (wire v3; v2 peers get the unknown-kind refusal). All
//!   admin frames route through one [`crate::admin::AdminSurface`] hook
//!   ([`TransportServer::bind_with_surface`]); [`TransportClient`]
//!   implements the same trait wire-forwarded, so admin tooling is
//!   transport-agnostic. Framing violations decode to a typed
//!   [`ProtocolError`] and close only the offending connection.
//! * [`net`](self) (internal) — a socket-agnostic stream substrate: the
//!   server and client are parameterized over unix-domain and TCP
//!   sockets ([`Endpoint`]), with `TCP_NODELAY` on every TCP connection
//!   (frames are written whole; Nagle could only add latency).
//! * [`TransportServer`] (`server.rs`) — accept loop + per-connection
//!   reader/writer threads feeding decoded requests into the
//!   [`crate::serving::MicroBatcher`] through its non-blocking callback
//!   API, so requests from *all* connections coalesce into shared
//!   `map_batch` waves and responses stream back per connection, matched
//!   by echoed request id. A decoded wire wave is submitted as ONE
//!   coalesced batch (`MicroBatcher::submit_wave`), the per-connection
//!   in-flight cap admits or sheds waves whole (never split across an
//!   `ERR_OVERLOAD` boundary), and replies to v3 peers pack into wave
//!   response frames. Binds a uds path ([`TransportServer::bind`]) or a
//!   TCP address ([`TransportServer::bind_tcp`], config
//!   `serving.listen`).
//! * [`TransportClient`] (`client.rs`) — sync and pipelined modes; the
//!   pipelined burst is what makes server-side coalescing reachable from
//!   a single closed-loop client ([`TransportClient::pipeline_waves`]
//!   packs it into wave frames), and is how `serve-bench --transport
//!   uds|tcp [--wave N]` drives its cross-process closed loop.
//!
//! The fan-out under all of this runs on the persistent
//! [`crate::exec::serve_pool`] — zero per-batch thread spawns on the
//! serve path.

pub mod wire;

mod client;
mod net;
mod server;

pub use client::{ClientFrameStats, TransportClient};
pub use net::Endpoint;
pub use server::{TransportServer, TransportStats, VocabAdmin, MAX_IN_FLIGHT};
pub use wire::{ProtocolError, Request, Response, MAX_SNAPSHOT_CHUNK};
