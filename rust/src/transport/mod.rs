//! L4 — the cross-process serving transport: the serving subsystem
//! (L3.5) behind a real wire.
//!
//! The paper's `O(D log n)` per-draw cost only dominates serving cost at
//! production scale if the plumbing around the tree walks is cheap and
//! shared-work amortization survives the process boundary. This layer
//! supplies both:
//!
//! * [`wire`] — a std-only, length-prefixed, versioned binary protocol
//!   over Unix domain sockets: request/response codecs for `sample`,
//!   `probability`, and `top_k`, with per-request seeds on the wire so
//!   served draws stay deterministic across process boundaries (the same
//!   (seed, query, epoch) yields byte-identical draws in-process and
//!   remotely). Framing violations decode to a typed
//!   [`ProtocolError`] and close only the offending connection.
//! * [`TransportServer`] (`server.rs`) — accept loop + per-connection
//!   reader/writer threads feeding decoded requests into the
//!   [`crate::serving::MicroBatcher`] through its non-blocking callback
//!   API, so requests from *all* connections coalesce into shared
//!   `map_batch` waves and responses stream back per connection, matched
//!   by echoed request id.
//! * [`TransportClient`] (`client.rs`) — sync and pipelined modes; the
//!   pipelined wave is what makes server-side coalescing reachable from
//!   a single closed-loop client, and is how `serve-bench --transport
//!   uds` drives its cross-process closed loop.
//!
//! The fan-out under all of this runs on the persistent
//! [`crate::exec::serve_pool`] — zero per-batch thread spawns on the
//! serve path.

pub mod wire;

mod client;
mod server;

pub use client::TransportClient;
pub use server::{TransportServer, TransportStats, VocabAdmin, MAX_IN_FLIGHT};
pub use wire::{ProtocolError, Request, Response};
