//! Accept loop feeding the serving micro-batcher — socket-agnostic: the
//! same server logic binds a unix-domain socket ([`TransportServer::bind`])
//! or a TCP listener ([`TransportServer::bind_tcp`], config key
//! `serving.listen`, `TCP_NODELAY` on every accepted connection), so
//! serving crosses machines with identical semantics.
//!
//! One thread accepts connections; each connection gets a reader thread
//! (decodes frames, submits to the [`MicroBatcher`] via its non-blocking
//! callback API — so one connection can keep many requests in flight and
//! they all coalesce with everyone else's) and a writer thread (drains
//! the connection's reply channel and encodes response frames, matched
//! to requests by the echoed id, possibly out of order).
//!
//! **Batched wave frames** (wire v3): a pipelined burst arriving as one
//! wave frame costs one header parse for the whole burst, and the
//! decoded sub-requests are submitted to the batcher as ONE coalesced
//! batch ([`MicroBatcher::submit_wave`]) — the wave is the batch. Once a
//! connection has sent a wave (proving it speaks v3), the writer packs
//! each drain of queued replies into wave response frames too, so the
//! reply direction amortizes headers the same way. v2 peers never see a
//! wave frame: their replies stay one frame per response.
//!
//! Framing violations answer with one `Error` frame (code
//! [`wire::ERR_PROTOCOL`], request id 0) and close that connection only
//! — the batcher and every other connection keep serving. Serve-level
//! failures (a query the sampler rejects) answer with an `Error` frame
//! carrying [`wire::ERR_SERVE`] and the connection stays open.
//!
//! **Backpressure** (per connection): at most [`MAX_IN_FLIGHT`] requests
//! may be awaiting replies — requests beyond the cap are *shed* with a
//! typed [`wire::ERR_OVERLOAD`] frame instead of being submitted, and
//! past a hard outstanding-reply ceiling the reader simply stops reading
//! the socket (classic flow control), so one slow pipelined client can
//! never balloon server memory. The cap is gated on *waves*, not
//! sub-requests: a wave is admitted in full (the soft cap may overshoot
//! by at most one wave, bounded by [`wire::MAX_WAVE`]) or shed in full —
//! never split across an `ERR_OVERLOAD` boundary. The batcher's reply
//! callbacks never block: pending batcher replies are bounded by the
//! in-flight cap, and overload/error frames by the reader throttle.
//!
//! **Admin frames**: `ADD_CLASSES`/`RETIRE_CLASSES`/`STATE_SNAPSHOT`
//! route to an optional [`crate::admin::AdminSurface`] hook (see
//! [`TransportServer::bind_with_surface`]) that applies the op through
//! the sampler writer as one epoch-versioned snapshot swap; without a
//! hook they answer [`wire::ERR_SERVE`]. A `STATE_SNAPSHOT` fetch
//! captures the full durable sampler state once and streams it back as
//! chunked [`wire::Response::SnapshotChunk`] frames sharing the request
//! id — each chunk under [`wire::MAX_SNAPSHOT_CHUNK`], so arbitrarily
//! large states respect the frame cap. The legacy [`VocabAdmin`] hook
//! ([`TransportServer::bind_with_admin`]) is kept one release as a
//! deprecated shim — it adapts into the surface but answers
//! `STATE_SNAPSHOT` with [`wire::ERR_SERVE`]. The read-only `STATS`
//! frame is answered inline on every server (no hook needed): the
//! batcher's serving snapshot ([`MicroBatcher::stats_json`]) merged
//! with this transport's own counter section, encoded with the in-crate
//! JSON emitter.
//!
//! **Telemetry**: connection readers record the per-request `decode`
//! stage (CPU-only frame parse, wave cost shared across sub-requests)
//! and writers the `encode_reply` stage into the batcher's
//! [`LiveRegistry`](crate::metrics::live::LiveRegistry), completing the
//! per-stage pipeline trace the batcher starts.

use super::net::{Endpoint, Listener, Stream};
use super::wire::{self, ProtocolError, RequestFrame, Response};
use crate::admin::{AdminOp, AdminResponse, AdminSurface};
use crate::json::Json;
use crate::linalg::Matrix;
use crate::metrics::live::Stage;
use crate::serving::{MicroBatcher, QueryReply, SubmitReply};
use std::io::{BufReader, Write};
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Per-connection cap on requests submitted to the batcher and awaiting
/// replies; beyond it requests are shed with [`wire::ERR_OVERLOAD`].
/// Checked per wave for wave frames — a wave is never split by the cap.
pub const MAX_IN_FLIGHT: usize = 1024;

/// Hard per-connection ceiling on outstanding reply frames of any kind
/// (served replies + shed errors). At the ceiling the reader stops
/// reading until the writer drains — socket-level flow control.
const MAX_OUTSTANDING: usize = 2 * MAX_IN_FLIGHT;

/// Reader park interval while throttled at [`MAX_OUTSTANDING`].
const THROTTLE_POLL: std::time::Duration = std::time::Duration::from_micros(50);

/// Upper bound on one continuous throttle park. The throttle exists to
/// bound memory against a peer that writes without reading; it must not
/// become a live-lock if the connection writer dies mid-backlog (its
/// `outstanding` decrements stop forever). After this grace the reader
/// proceeds to the next read regardless: on a dead socket that read
/// errors out and the handler exits, and on a merely-slow peer the
/// overshoot is bounded to one frame per grace period.
const THROTTLE_GRACE: std::time::Duration = std::time::Duration::from_secs(2);

/// Max sub-responses the writer packs into one wave response frame; the
/// byte bound is the shared [`wire::WAVE_SOFT_PAYLOAD`].
const WAVE_PACK_MAX: usize = 256;

/// Legacy hook that applies admin (class-universe) mutations — the wire
/// dialect that predates the unified [`AdminSurface`]. Implemented over
/// the serving layer's `SamplerWriter` (see
/// `crate::serving::run_closed_loop`): apply to the shadow, publish one
/// epoch-versioned swap, return the epoch — readers can never observe a
/// half-grown tree. Implementations own the ingestion contract for raw
/// wire embeddings — normalize rows if the served sampler assumes the
/// normalized-embedding regime (the in-crate impl does).
///
/// New embedders should implement [`AdminSurface`] and bind via
/// [`TransportServer::bind_with_surface`] instead: the surface speaks
/// typed ops/errors and additionally answers `STATE_SNAPSHOT` fetches.
pub trait VocabAdmin: Send + Sync {
    /// Append `rows` classes (row-major `data`, width `dim`); returns
    /// the assigned ids and the publish epoch.
    fn add_classes(
        &self,
        dim: usize,
        rows: usize,
        data: Vec<f32>,
    ) -> Result<(Vec<u32>, u64), String>;

    /// Retire live classes; returns the publish epoch.
    fn retire_classes(&self, ids: &[u32]) -> Result<u64, String>;
}

/// Adapter giving a legacy [`VocabAdmin`] the [`AdminSurface`] shape so
/// the server routes every admin frame through one hook type. Vocab
/// churn delegates; snapshot/restore answer
/// [`crate::admin::AdminError::Unsupported`] (the legacy dialect
/// predates durability).
struct LegacyVocabAdmin(Arc<dyn VocabAdmin>);

impl AdminSurface for LegacyVocabAdmin {
    fn admin(
        &mut self,
        op: AdminOp,
    ) -> Result<AdminResponse, crate::admin::AdminError> {
        use crate::admin::AdminError;
        match op {
            AdminOp::AddClasses { embeddings } => {
                let (dim, rows) = (embeddings.cols(), embeddings.rows());
                let (ids, epoch) = self
                    .0
                    .add_classes(dim, rows, embeddings.into_vec())
                    .map_err(AdminError::Transport)?;
                Ok(AdminResponse::Added { ids, epoch })
            }
            AdminOp::RetireClasses { ids } => {
                let epoch = self
                    .0
                    .retire_classes(&ids)
                    .map_err(AdminError::Transport)?;
                Ok(AdminResponse::Retired { epoch })
            }
            AdminOp::Snapshot | AdminOp::Restore { .. } => {
                Err(AdminError::Unsupported("legacy VocabAdmin hook"))
            }
        }
    }
}

/// Transport-level counters (for tests and ops visibility).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Connections accepted so far.
    pub connections: u64,
    /// Serve requests decoded (wave sub-requests included).
    pub requests: u64,
    /// Frames carrying requests parsed (singles + waves): the
    /// numerator of the per-request header overhead —
    /// `request_frames / requests` is 1.0 for a single-frame client and
    /// `≈ 1/wave` for a wave-batched one.
    pub request_frames: u64,
    /// Wave frames among `request_frames`.
    pub wave_frames: u64,
    /// Frames carrying responses written (wave packing makes this less
    /// than the response count for v3 connections).
    pub response_frames: u64,
    /// Framing violations that closed a connection.
    pub protocol_errors: u64,
    /// Admin (add/retire) frames applied.
    pub admin_requests: u64,
    /// Requests shed with [`wire::ERR_OVERLOAD`] (per-connection
    /// in-flight cap exceeded; every sub-request of a shed wave counts).
    pub overloads: u64,
}

struct Shared {
    batcher: Arc<MicroBatcher>,
    admin: Option<Arc<Mutex<dyn AdminSurface + Send>>>,
    shutdown: AtomicBool,
    connections: AtomicU64,
    requests: AtomicU64,
    request_frames: AtomicU64,
    wave_frames: AtomicU64,
    response_frames: AtomicU64,
    protocol_errors: AtomicU64,
    admin_requests: AtomicU64,
    overloads: AtomicU64,
    /// Clones of *live* connection streams keyed by connection id, so
    /// shutdown can unblock their reader threads with a socket-level
    /// `shutdown(2)`. Handlers deregister themselves on exit, so this
    /// tracks open connections only — no fd growth under churn.
    streams: Mutex<Vec<(u64, Stream)>>,
    /// Live connection-handler join handles (pushed by the accept
    /// thread, pruned of finished threads on each accept, drained on
    /// drop).
    handlers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Shared {
    fn unblock_connections(&self) {
        for (_, s) in self.streams.lock().unwrap().iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            request_frames: self.request_frames.load(Ordering::Relaxed),
            wave_frames: self.wave_frames.load(Ordering::Relaxed),
            response_frames: self.response_frames.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            admin_requests: self.admin_requests.load(Ordering::Relaxed),
            overloads: self.overloads.load(Ordering::Relaxed),
        }
    }

    /// The full STATS wire answer: the batcher's serving snapshot
    /// (batcher counters, snapshot-server state, telemetry registry)
    /// plus this transport's own counter section.
    fn stats_json(&self) -> Json {
        let mut j = self.batcher.stats_json();
        let s = self.stats();
        if let Json::Obj(map) = &mut j {
            map.insert(
                "transport".to_string(),
                Json::obj(vec![
                    ("connections", Json::from(s.connections as usize)),
                    ("requests", Json::from(s.requests as usize)),
                    ("request_frames", Json::from(s.request_frames as usize)),
                    ("wave_frames", Json::from(s.wave_frames as usize)),
                    ("response_frames", Json::from(s.response_frames as usize)),
                    ("protocol_errors", Json::from(s.protocol_errors as usize)),
                    ("admin_requests", Json::from(s.admin_requests as usize)),
                    ("overloads", Json::from(s.overloads as usize)),
                ]),
            );
        }
        j
    }
}

/// A running serving transport endpoint — unix-socket or TCP. Dropping
/// it shuts down the accept loop and every connection, then removes the
/// socket file (uds only).
pub struct TransportServer {
    shared: Arc<Shared>,
    endpoint: Endpoint,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl TransportServer {
    /// Bind a unix socket at `path` (replacing a stale socket file) and
    /// start serving the given batcher. The listener is bound before
    /// this returns, so clients may connect immediately.
    pub fn bind(
        path: impl AsRef<Path>,
        batcher: Arc<MicroBatcher>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_uds_inner(path, batcher, None)
    }

    /// [`TransportServer::bind`] plus an [`AdminSurface`] hook, enabling
    /// the `ADD_CLASSES`/`RETIRE_CLASSES`/`STATE_SNAPSHOT` admin frames
    /// on every connection. The surface is behind a mutex because admin
    /// mutations are writer-serialized by design — churn is rare and
    /// epoch-published, never on the query hot path.
    pub fn bind_with_surface(
        path: impl AsRef<Path>,
        batcher: Arc<MicroBatcher>,
        surface: Arc<Mutex<dyn AdminSurface + Send>>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_uds_inner(path, batcher, Some(surface))
    }

    /// [`TransportServer::bind`] plus a legacy [`VocabAdmin`] hook.
    #[deprecated(
        note = "use bind_with_surface (typed AdminSurface hook; also answers STATE_SNAPSHOT)"
    )]
    pub fn bind_with_admin(
        path: impl AsRef<Path>,
        batcher: Arc<MicroBatcher>,
        admin: Arc<dyn VocabAdmin>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_uds_inner(
            path,
            batcher,
            Some(Arc::new(Mutex::new(LegacyVocabAdmin(admin)))),
        )
    }

    /// Bind a TCP listener at `addr` (e.g. `"127.0.0.1:7411"`; port `0`
    /// asks the kernel for an ephemeral port — read the real one back
    /// via [`TransportServer::endpoint`]) and start serving the given
    /// batcher. This is what lets serving cross machines: the wire
    /// protocol, backpressure, and determinism contracts are identical
    /// to the unix-socket transport.
    pub fn bind_tcp(
        addr: &str,
        batcher: Arc<MicroBatcher>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_tcp_inner(addr, batcher, None)
    }

    /// [`TransportServer::bind_tcp`] plus an [`AdminSurface`] hook.
    pub fn bind_tcp_with_surface(
        addr: &str,
        batcher: Arc<MicroBatcher>,
        surface: Arc<Mutex<dyn AdminSurface + Send>>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_tcp_inner(addr, batcher, Some(surface))
    }

    /// [`TransportServer::bind_tcp`] plus a legacy [`VocabAdmin`] hook.
    #[deprecated(
        note = "use bind_tcp_with_surface (typed AdminSurface hook; also answers STATE_SNAPSHOT)"
    )]
    pub fn bind_tcp_with_admin(
        addr: &str,
        batcher: Arc<MicroBatcher>,
        admin: Arc<dyn VocabAdmin>,
    ) -> std::io::Result<TransportServer> {
        Self::bind_tcp_inner(
            addr,
            batcher,
            Some(Arc::new(Mutex::new(LegacyVocabAdmin(admin)))),
        )
    }

    fn bind_uds_inner(
        path: impl AsRef<Path>,
        batcher: Arc<MicroBatcher>,
        admin: Option<Arc<Mutex<dyn AdminSurface + Send>>>,
    ) -> std::io::Result<TransportServer> {
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = Listener::Uds(UnixListener::bind(&path)?);
        Self::start(listener, Endpoint::Uds(path), batcher, admin)
    }

    fn bind_tcp_inner(
        addr: &str,
        batcher: Arc<MicroBatcher>,
        admin: Option<Arc<Mutex<dyn AdminSurface + Send>>>,
    ) -> std::io::Result<TransportServer> {
        let listener = std::net::TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Self::start(Listener::Tcp(listener), Endpoint::Tcp(local), batcher, admin)
    }

    fn start(
        listener: Listener,
        endpoint: Endpoint,
        batcher: Arc<MicroBatcher>,
        admin: Option<Arc<Mutex<dyn AdminSurface + Send>>>,
    ) -> std::io::Result<TransportServer> {
        // Nonblocking accept + a short poll lets shutdown terminate the
        // accept thread deterministically — a blocking accept(2) could
        // only be woken by connecting to the endpoint, which hangs if it
        // no longer routes to this listener (unlinked or rebound).
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            batcher,
            admin,
            shutdown: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            request_frames: AtomicU64::new(0),
            wave_frames: AtomicU64::new(0),
            response_frames: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            admin_requests: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            streams: Mutex::new(Vec::new()),
            handlers: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("rfsm-transport-accept".into())
                .spawn(move || accept_loop(&listener, &shared))
                .expect("spawn transport accept loop")
        };
        Ok(TransportServer { shared, endpoint, accept: Some(accept) })
    }

    /// Where clients connect: the uds path or the actual TCP address
    /// (ephemeral port resolved).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// The socket path clients connect to (unix-socket servers only;
    /// panics on a TCP server — use [`TransportServer::endpoint`]).
    pub fn path(&self) -> &Path {
        match &self.endpoint {
            Endpoint::Uds(p) => p,
            Endpoint::Tcp(a) => {
                panic!("TransportServer::path on tcp endpoint {a} — use endpoint()")
            }
        }
    }

    pub fn stats(&self) -> TransportStats {
        self.shared.stats()
    }

    /// The JSON snapshot a `STATS` wire scrape of this server returns
    /// (also reachable in-process, e.g. for BENCH records).
    pub fn stats_json(&self) -> Json {
        self.shared.stats_json()
    }
}

impl Drop for TransportServer {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        // Unblock every connection reader; they see EOF and exit. The
        // accept thread notices `shutdown` on its next poll tick.
        self.shared.unblock_connections();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // Second pass AFTER the accept thread is gone: a connection
        // accepted concurrently with the first pass may have registered
        // its stream only after we iterated — with the accept loop
        // joined, the registry is complete, so no straggler reader can
        // keep a handler join below blocked.
        self.shared.unblock_connections();
        let handlers: Vec<_> =
            self.shared.handlers.lock().unwrap().drain(..).collect();
        for h in handlers {
            let _ = h.join();
        }
        if let Endpoint::Uds(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// How long the accept thread parks between polls when idle — bounds
/// both shutdown latency and the cost of an accept-error storm (EMFILE).
const ACCEPT_POLL: std::time::Duration = std::time::Duration::from_millis(5);

fn accept_loop(listener: &Listener, shared: &Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let stream = match listener.accept() {
            Ok(stream) => stream,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
            Err(_) => {
                // Accept errors (e.g. EMFILE under fd pressure) must not
                // busy-spin the accept thread.
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
        };
        // The listener is nonblocking for the poll above; accepted
        // connection sockets must block normally for their reader/writer
        // threads.
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let conn_id = shared.connections.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.streams.lock().unwrap().push((conn_id, clone));
        }
        let handler = {
            let shared = Arc::clone(shared);
            std::thread::Builder::new()
                .name("rfsm-transport-conn".into())
                .spawn(move || handle_connection(&shared, conn_id, stream))
        };
        let mut handlers = shared.handlers.lock().unwrap();
        // Prune finished threads so churny workloads don't accumulate
        // handles (their connections already deregistered themselves).
        handlers.retain(|h| !h.is_finished());
        match handler {
            Ok(h) => handlers.push(h),
            Err(_) => {
                drop(handlers);
                // The handler never ran, so deregister its stream here.
                shared.streams.lock().unwrap().retain(|(id, _)| *id != conn_id);
            }
        }
    }
}

fn reply_to_response(result: Result<QueryReply, String>) -> Response {
    match result {
        Ok(QueryReply::Sample(r)) => Response::Sample {
            epoch: r.epoch,
            ids: r.draw.ids,
            probs: r.draw.probs,
        },
        Ok(QueryReply::Probability { q, epoch }) => {
            Response::Probability { epoch, q }
        }
        Ok(QueryReply::TopK { items, epoch }) => Response::TopK { epoch, items },
        Err(message) => Response::Error { code: wire::ERR_SERVE, message },
    }
}

fn overload_response() -> Response {
    Response::Error {
        code: wire::ERR_OVERLOAD,
        message: format!(
            "connection exceeded {MAX_IN_FLIGHT} in-flight requests"
        ),
    }
}

fn handle_connection(shared: &Arc<Shared>, conn_id: u64, stream: Stream) {
    // Whatever path exits this handler, drop the registry's stream clone
    // so closed connections release their duplicated fd immediately.
    struct Deregister<'a> {
        shared: &'a Shared,
        conn_id: u64,
    }
    impl Drop for Deregister<'_> {
        fn drop(&mut self) {
            self.shared
                .streams
                .lock()
                .unwrap()
                .retain(|(id, _)| *id != self.conn_id);
        }
    }
    let _deregister = Deregister { shared: shared.as_ref(), conn_id };
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<(u64, Response)>();
    // Replies of any kind awaiting the writer (served + error frames):
    // incremented by the reader per answered request, decremented by the
    // writer per response written. Bounds this connection's queued memory.
    let outstanding = Arc::new(AtomicUsize::new(0));
    // Subset submitted to the batcher and not yet answered — the soft
    // cap that sheds with ERR_OVERLOAD.
    let in_flight = Arc::new(AtomicUsize::new(0));
    // Set once the peer sends a wave frame (proving it speaks wire v3);
    // from then on the writer may pack replies into wave frames.
    let wants_wave = Arc::new(AtomicBool::new(false));
    let writer = {
        let outstanding = Arc::clone(&outstanding);
        let wants_wave = Arc::clone(&wants_wave);
        let shared_w = Arc::clone(shared);
        std::thread::Builder::new()
            .name("rfsm-transport-write".into())
            .spawn(move || {
                writer_loop(writer_stream, &rx, &outstanding, &wants_wave, &shared_w)
            })
    };
    let mut reader = BufReader::new(stream);
    let telemetry = shared.batcher.telemetry().clone();
    'conn: loop {
        // Hard flow control: past the outstanding-reply ceiling, stop
        // reading the socket (up to THROTTLE_GRACE) until the writer
        // drains — the kernel's socket buffers then stall the over-eager
        // peer, and server memory stays bounded no matter how hard it
        // pipelines. The grace bound keeps a dead writer (peer crashed
        // mid-backlog) from parking this thread forever: the next read
        // observes the dead socket and exits.
        let mut throttled = std::time::Duration::ZERO;
        while outstanding.load(Ordering::Acquire) >= MAX_OUTSTANDING
            && !shared.shutdown.load(Ordering::Relaxed)
            && throttled < THROTTLE_GRACE
        {
            std::thread::sleep(THROTTLE_POLL);
            throttled += THROTTLE_POLL;
        }
        match wire::read_request_frame_traced(&mut reader) {
            Ok(None) => break, // clean EOF
            Ok(Some((RequestFrame::Single(id, request), decode_ns))) => {
                shared.request_frames.fetch_add(1, Ordering::Relaxed);
                if request.is_admin() {
                    if !answer_admin(shared, &tx, &outstanding, id, request) {
                        break;
                    }
                    continue;
                }
                telemetry.record_stage_ns(Stage::Decode, decode_ns);
                shared.requests.fetch_add(1, Ordering::Relaxed);
                if in_flight.load(Ordering::Acquire) >= MAX_IN_FLIGHT {
                    // Shed: typed overload error, request never reaches
                    // the batcher. The connection stays usable.
                    shared.overloads.fetch_add(1, Ordering::Relaxed);
                    outstanding.fetch_add(1, Ordering::AcqRel);
                    if tx.send((id, overload_response())).is_err() {
                        break;
                    }
                    continue;
                }
                let (h, query) = request.into_query();
                let reply_tx = tx.clone();
                outstanding.fetch_add(1, Ordering::AcqRel);
                in_flight.fetch_add(1, Ordering::AcqRel);
                let in_flight_cb = Arc::clone(&in_flight);
                let accepted = shared.batcher.submit(h, query, move |res| {
                    in_flight_cb.fetch_sub(1, Ordering::AcqRel);
                    // A closed connection drops the receiver; that is the
                    // client's problem, not the batcher's.
                    let _ = reply_tx.send((id, reply_to_response(res)));
                });
                if !accepted {
                    // The callback was dropped unserved: undo its
                    // accounting and answer shutdown ourselves.
                    in_flight.fetch_sub(1, Ordering::AcqRel);
                    let _ = tx.send((
                        id,
                        Response::Error {
                            code: wire::ERR_SHUTDOWN,
                            message: "server shutting down".into(),
                        },
                    ));
                    break;
                }
            }
            Ok(Some((RequestFrame::Wave(subs), decode_ns))) => {
                shared.request_frames.fetch_add(1, Ordering::Relaxed);
                shared.wave_frames.fetch_add(1, Ordering::Relaxed);
                wants_wave.store(true, Ordering::Release);
                let serve_subs =
                    subs.iter().filter(|(_, r)| !r.is_admin()).count() as u64;
                shared.requests.fetch_add(serve_subs, Ordering::Relaxed);
                // The wave's one header+payload parse is shared: charge
                // each serve sub-request its share, keeping the decode
                // stage count equal to the request count.
                if serve_subs > 0 {
                    let share = decode_ns / serve_subs;
                    for _ in 0..serve_subs {
                        telemetry.record_stage_ns(Stage::Decode, share);
                    }
                }
                // Wave-gated backpressure: the in-flight cap is checked
                // ONCE for the whole wave — it is admitted in full
                // (overshooting the soft cap by at most MAX_WAVE) or
                // shed in full, never split across an ERR_OVERLOAD
                // boundary.
                let shed = serve_subs > 0
                    && in_flight.load(Ordering::Acquire) >= MAX_IN_FLIGHT;
                let mut entries: Vec<(Vec<f32>, crate::sampler::ServeQuery, SubmitReply)> =
                    Vec::with_capacity(subs.len());
                let mut entry_ids = Vec::with_capacity(subs.len());
                for (id, request) in subs {
                    if request.is_admin() {
                        if !answer_admin(shared, &tx, &outstanding, id, request)
                        {
                            break 'conn;
                        }
                    } else if shed {
                        shared.overloads.fetch_add(1, Ordering::Relaxed);
                        outstanding.fetch_add(1, Ordering::AcqRel);
                        if tx.send((id, overload_response())).is_err() {
                            break 'conn;
                        }
                    } else {
                        let (h, query) = request.into_query();
                        let reply_tx = tx.clone();
                        outstanding.fetch_add(1, Ordering::AcqRel);
                        in_flight.fetch_add(1, Ordering::AcqRel);
                        let in_flight_cb = Arc::clone(&in_flight);
                        entry_ids.push(id);
                        entries.push((
                            h,
                            query,
                            Box::new(move |res| {
                                in_flight_cb.fetch_sub(1, Ordering::AcqRel);
                                let _ = reply_tx
                                    .send((id, reply_to_response(res)));
                            }),
                        ));
                    }
                }
                if !entries.is_empty() {
                    let n = entries.len();
                    // One decoded wave lands as one coalesced batch.
                    if !shared.batcher.submit_wave(entries) {
                        // Callbacks were dropped unserved: undo their
                        // accounting and answer shutdown per sub-request
                        // (outstanding was already counted above).
                        in_flight.fetch_sub(n, Ordering::AcqRel);
                        for id in entry_ids {
                            let _ = tx.send((
                                id,
                                Response::Error {
                                    code: wire::ERR_SHUTDOWN,
                                    message: "server shutting down".into(),
                                },
                            ));
                        }
                        break;
                    }
                }
            }
            Err(ProtocolError::Io(_)) => {
                // Dead socket: nothing to answer.
                break;
            }
            Err(e) => {
                // Framing violation (truncated/oversized/bad version or
                // kind/malformed): one typed error frame (request id 0 =
                // connection-level), best-effort since a truncating peer
                // may already be gone, then close. The batcher never saw
                // the bytes, so it cannot be poisoned.
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                outstanding.fetch_add(1, Ordering::AcqRel);
                let _ = tx.send((
                    0,
                    Response::Error {
                        code: wire::ERR_PROTOCOL,
                        message: e.to_string(),
                    },
                ));
                break;
            }
        }
    }
    // Dropping our sender lets the writer exit once every in-flight
    // reply (whose callbacks hold clones) has been delivered.
    drop(tx);
    if let Ok(w) = writer {
        let _ = w.join();
    }
}

/// Answer one admin frame inline (mutations are writer-serialized, not
/// batched); returns `false` when the reply channel is gone and the
/// connection should close. The read-only `STATS` frame is answered on
/// every server — only mutations and snapshot fetches need the
/// [`AdminSurface`] hook. A `STATE_SNAPSHOT` fetch may enqueue several
/// [`Response::SnapshotChunk`] replies under the one request id; each
/// extra chunk bumps `outstanding` so the writer's per-response
/// accounting (and the backpressure bound it feeds) stays exact.
fn answer_admin(
    shared: &Shared,
    tx: &mpsc::Sender<(u64, Response)>,
    outstanding: &AtomicUsize,
    id: u64,
    request: wire::Request,
) -> bool {
    shared.admin_requests.fetch_add(1, Ordering::Relaxed);
    outstanding.fetch_add(1, Ordering::AcqRel);
    let resp = match request {
        wire::Request::Stats => {
            Response::Stats { json: shared.stats_json().to_string() }
        }
        // Read-only like STATS: answered on every server, straight off
        // the current snapshot.
        wire::Request::Mass { h } => {
            let (mass, epoch) = shared.batcher.mass(&h);
            Response::Mass { epoch, mass }
        }
        wire::Request::SnapshotFetch { max_chunk } => {
            return answer_snapshot_fetch(
                shared,
                tx,
                outstanding,
                id,
                max_chunk,
            );
        }
        request => match &shared.admin {
            None => Response::Error {
                code: wire::ERR_SERVE,
                message: "admin frames not enabled on this server".into(),
            },
            Some(admin) => apply_admin(admin, request),
        },
    };
    tx.send((id, resp)).is_ok()
}

fn apply_admin(
    admin: &Mutex<dyn AdminSurface + Send>,
    request: wire::Request,
) -> Response {
    match request {
        wire::Request::AddClasses { dim, embeddings } => {
            let dim = dim as usize;
            if dim == 0 || embeddings.len() % dim != 0 {
                return Response::Error {
                    code: wire::ERR_SERVE,
                    message: "AddClasses: data is not rows×dim".into(),
                };
            }
            let rows = embeddings.len() / dim;
            let op = AdminOp::AddClasses {
                embeddings: Matrix::from_vec(rows, dim, embeddings),
            };
            match admin.lock().expect("admin surface poisoned").admin(op) {
                Ok(AdminResponse::Added { ids, epoch }) => {
                    Response::AddClasses { epoch, ids }
                }
                Ok(other) => mismatched_admin_reply("add_classes", &other),
                Err(e) => Response::Error {
                    code: wire::ERR_SERVE,
                    message: e.to_string(),
                },
            }
        }
        wire::Request::RetireClasses { ids } => {
            let count = ids.len() as u32;
            let op = AdminOp::RetireClasses { ids };
            match admin.lock().expect("admin surface poisoned").admin(op) {
                Ok(AdminResponse::Retired { epoch }) => {
                    Response::RetireClasses { epoch, count }
                }
                Ok(other) => mismatched_admin_reply("retire_classes", &other),
                Err(e) => Response::Error {
                    code: wire::ERR_SERVE,
                    message: e.to_string(),
                },
            }
        }
        _ => unreachable!("apply_admin: non-admin frame"),
    }
}

/// A surface answered an op with the wrong response variant — a bug in
/// the embedder's [`AdminSurface`] impl, reported to the client rather
/// than crashing the serving thread.
fn mismatched_admin_reply(wanted: &str, got: &AdminResponse) -> Response {
    Response::Error {
        code: wire::ERR_SERVE,
        message: format!("admin surface answered {got:?} to {wanted}"),
    }
}

/// Stream the full durable sampler state back as chunked
/// `STATE_SNAPSHOT` frames. The state is captured and encoded exactly
/// once (readers of a half-applied epoch are impossible — the surface
/// reads the pinned snapshot), then split into chunks of at most
/// `max_chunk` bytes (`0` means [`wire::MAX_SNAPSHOT_CHUNK`], and the
/// cap is enforced regardless) that all share the request id. The first
/// chunk rides the `outstanding` slot `answer_admin` already took; each
/// later chunk takes its own before being queued.
fn answer_snapshot_fetch(
    shared: &Shared,
    tx: &mpsc::Sender<(u64, Response)>,
    outstanding: &AtomicUsize,
    id: u64,
    max_chunk: u32,
) -> bool {
    let encoded = match &shared.admin {
        None => Err("admin frames not enabled on this server".to_string()),
        Some(admin) => {
            let got =
                admin.lock().expect("admin surface poisoned").admin(
                    AdminOp::Snapshot,
                );
            match got {
                Ok(AdminResponse::Snapshot { snapshot }) => {
                    let epoch = snapshot.epoch;
                    Ok((crate::snapshot::encode(&snapshot), epoch))
                }
                Ok(other) => {
                    Err(format!("admin surface answered {other:?} to snapshot"))
                }
                Err(e) => Err(e.to_string()),
            }
        }
    };
    let (bytes, epoch) = match encoded {
        Ok(x) => x,
        Err(message) => {
            let resp = Response::Error { code: wire::ERR_SERVE, message };
            return tx.send((id, resp)).is_ok();
        }
    };
    let max = if max_chunk == 0 {
        wire::MAX_SNAPSHOT_CHUNK
    } else {
        (max_chunk as usize).min(wire::MAX_SNAPSHOT_CHUNK)
    }
    .max(1);
    let total = bytes.len() as u64;
    let mut offset = 0usize;
    let mut first = true;
    // An empty encoding still answers one empty chunk (offset 0 == total
    // 0 marks completion), so the loop shape is do-while.
    loop {
        let end = (offset + max).min(bytes.len());
        let chunk = Response::SnapshotChunk {
            epoch,
            total,
            offset: offset as u64,
            data: bytes[offset..end].to_vec(),
        };
        if !first {
            outstanding.fetch_add(1, Ordering::AcqRel);
        }
        first = false;
        if tx.send((id, chunk)).is_err() {
            return false;
        }
        offset = end;
        if offset >= bytes.len() {
            return true;
        }
    }
}

fn writer_loop(
    mut stream: Stream,
    rx: &mpsc::Receiver<(u64, Response)>,
    outstanding: &AtomicUsize,
    wants_wave: &AtomicBool,
    shared: &Shared,
) {
    // Zero-copy frame encode: every response of a drain wave is encoded
    // into this one reused buffer (header first, length backfilled) and
    // written with a single write_all — no per-frame Vec, no BufWriter
    // double copy. The buffer's capacity persists across waves, but is
    // clamped back after an oversized wave so one burst of huge replies
    // cannot pin its high-water allocation for the connection's
    // lifetime (that would quietly undo the backpressure memory bound).
    const BUF_KEEP: usize = 256 * 1024;
    let mut buf: Vec<u8> = Vec::with_capacity(16 * 1024);
    let telemetry = shared.batcher.telemetry().clone();
    loop {
        let first = match rx.recv() {
            Ok(x) => x,
            Err(_) => break,
        };
        // Encode-stage clock: starts after the blocking recv (socket
        // and channel waits excluded — CPU cost only) and stops before
        // the socket write.
        let encode_t0 = Instant::now();
        buf.clear();
        // Drain everything currently queued, then write once — batches
        // response frames the same way requests coalesce.
        let responses;
        if wants_wave.load(Ordering::Acquire) {
            // v3 peer: pack the drain into wave frames — one header per
            // packed group instead of per response. Chunked by count and
            // by a soft byte bound so no frame approaches MAX_PAYLOAD.
            // (A lone reply still goes as a plain single frame.)
            let mut batch: Vec<(u64, Response)> = vec![first];
            while let Ok(x) = rx.try_recv() {
                batch.push(x);
            }
            responses = batch.len();
            if responses == 1 {
                let (id, resp) = &batch[0];
                wire::encode_response(&mut buf, *id, resp);
                shared.response_frames.fetch_add(1, Ordering::Relaxed);
            } else {
                let mut frames = 0u64;
                let mut it = batch.into_iter().peekable();
                while it.peek().is_some() {
                    let frame_start = buf.len();
                    let mut w =
                        wire::WaveEncoder::begin_response_wave(&mut buf);
                    while let Some((id, resp)) = it.next_if(|_| {
                        w.count() < WAVE_PACK_MAX
                            && (w.count() == 0
                                || buf.len() - frame_start
                                    < wire::WAVE_SOFT_PAYLOAD)
                    }) {
                        w.push_response(&mut buf, id, &resp);
                    }
                    w.finish(&mut buf);
                    frames += 1;
                }
                shared.response_frames.fetch_add(frames, Ordering::Relaxed);
            }
        } else {
            // v2/sync peer: encode straight from the channel into the
            // reused buffer — the original zero-allocation drain (no
            // intermediate Vec on the per-response hot path).
            let mut n = 0usize;
            wire::encode_response(&mut buf, first.0, &first.1);
            n += 1;
            while let Ok((id, resp)) = rx.try_recv() {
                wire::encode_response(&mut buf, id, &resp);
                n += 1;
            }
            responses = n;
            shared
                .response_frames
                .fetch_add(responses as u64, Ordering::Relaxed);
        }
        // Each response in the drain is charged its share of the one
        // encode pass, so the encode_reply stage count matches the
        // response count.
        let encode_share = encode_t0.elapsed().as_nanos() as u64 / responses as u64;
        for _ in 0..responses {
            telemetry.record_stage_ns(Stage::EncodeReply, encode_share);
        }
        let ok = stream.write_all(&buf).is_ok();
        outstanding.fetch_sub(responses, Ordering::AcqRel);
        if buf.capacity() > BUF_KEEP {
            buf = Vec::with_capacity(BUF_KEEP);
        }
        if !ok || stream.flush().is_err() {
            break;
        }
    }
}
