//! Unix-domain-socket accept loop feeding the serving micro-batcher.
//!
//! One thread accepts connections; each connection gets a reader thread
//! (decodes frames, submits to the [`MicroBatcher`] via its non-blocking
//! callback API — so one connection can keep many requests in flight and
//! they all coalesce with everyone else's) and a writer thread (drains
//! the connection's reply channel and encodes response frames, matched
//! to requests by the echoed id, possibly out of order).
//!
//! Framing violations answer with one `Error` frame (code
//! [`wire::ERR_PROTOCOL`], request id 0) and close that connection only
//! — the batcher and every other connection keep serving. Serve-level
//! failures (a query the sampler rejects) answer with an `Error` frame
//! carrying [`wire::ERR_SERVE`] and the connection stays open.

use super::wire::{self, ProtocolError, Response};
use crate::serving::{MicroBatcher, QueryReply};
use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Transport-level counters (for tests and ops visibility).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Connections accepted so far.
    pub connections: u64,
    /// Request frames decoded and submitted to the batcher.
    pub requests: u64,
    /// Framing violations that closed a connection.
    pub protocol_errors: u64,
}

struct Shared {
    batcher: Arc<MicroBatcher>,
    shutdown: AtomicBool,
    connections: AtomicU64,
    requests: AtomicU64,
    protocol_errors: AtomicU64,
    /// Clones of *live* connection streams keyed by connection id, so
    /// shutdown can unblock their reader threads with a socket-level
    /// `shutdown(2)`. Handlers deregister themselves on exit, so this
    /// tracks open connections only — no fd growth under churn.
    streams: Mutex<Vec<(u64, UnixStream)>>,
    /// Live connection-handler join handles (pushed by the accept
    /// thread, pruned of finished threads on each accept, drained on
    /// drop).
    handlers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Shared {
    fn unblock_connections(&self) {
        for (_, s) in self.streams.lock().unwrap().iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// A running serving transport endpoint. Dropping it shuts down the
/// accept loop and every connection, then removes the socket file.
pub struct TransportServer {
    shared: Arc<Shared>,
    path: PathBuf,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl TransportServer {
    /// Bind a unix socket at `path` (replacing a stale socket file) and
    /// start serving the given batcher. The listener is bound before
    /// this returns, so clients may connect immediately.
    pub fn bind(
        path: impl AsRef<Path>,
        batcher: Arc<MicroBatcher>,
    ) -> std::io::Result<TransportServer> {
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        // Nonblocking accept + a short poll lets shutdown terminate the
        // accept thread deterministically — a blocking accept(2) could
        // only be woken by connecting to `path`, which hangs if the path
        // no longer routes to this listener (unlinked or rebound).
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            batcher,
            shutdown: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            streams: Mutex::new(Vec::new()),
            handlers: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("rfsm-transport-accept".into())
                .spawn(move || accept_loop(&listener, &shared))
                .expect("spawn transport accept loop")
        };
        Ok(TransportServer { shared, path, accept: Some(accept) })
    }

    /// The socket path clients connect to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn stats(&self) -> TransportStats {
        TransportStats {
            connections: self.shared.connections.load(Ordering::Relaxed),
            requests: self.shared.requests.load(Ordering::Relaxed),
            protocol_errors: self.shared.protocol_errors.load(Ordering::Relaxed),
        }
    }
}

impl Drop for TransportServer {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        // Unblock every connection reader; they see EOF and exit. The
        // accept thread notices `shutdown` on its next poll tick.
        self.shared.unblock_connections();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // Second pass AFTER the accept thread is gone: a connection
        // accepted concurrently with the first pass may have registered
        // its stream only after we iterated — with the accept loop
        // joined, the registry is complete, so no straggler reader can
        // keep a handler join below blocked.
        self.shared.unblock_connections();
        let handlers: Vec<_> =
            self.shared.handlers.lock().unwrap().drain(..).collect();
        for h in handlers {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

/// How long the accept thread parks between polls when idle — bounds
/// both shutdown latency and the cost of an accept-error storm (EMFILE).
const ACCEPT_POLL: std::time::Duration = std::time::Duration::from_millis(5);

fn accept_loop(listener: &UnixListener, shared: &Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let stream = match listener.accept() {
            Ok((stream, _addr)) => stream,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
            Err(_) => {
                // Accept errors (e.g. EMFILE under fd pressure) must not
                // busy-spin the accept thread.
                std::thread::sleep(ACCEPT_POLL);
                continue;
            }
        };
        // The listener is nonblocking for the poll above; accepted
        // connection sockets must block normally for their reader/writer
        // threads.
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let conn_id = shared.connections.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.streams.lock().unwrap().push((conn_id, clone));
        }
        let handler = {
            let shared = Arc::clone(shared);
            std::thread::Builder::new()
                .name("rfsm-transport-conn".into())
                .spawn(move || handle_connection(&shared, conn_id, stream))
        };
        let mut handlers = shared.handlers.lock().unwrap();
        // Prune finished threads so churny workloads don't accumulate
        // handles (their connections already deregistered themselves).
        handlers.retain(|h| !h.is_finished());
        match handler {
            Ok(h) => handlers.push(h),
            Err(_) => {
                drop(handlers);
                // The handler never ran, so deregister its stream here.
                shared.streams.lock().unwrap().retain(|(id, _)| *id != conn_id);
            }
        }
    }
}

fn reply_to_response(result: Result<QueryReply, String>) -> Response {
    match result {
        Ok(QueryReply::Sample(r)) => Response::Sample {
            epoch: r.epoch,
            ids: r.draw.ids,
            probs: r.draw.probs,
        },
        Ok(QueryReply::Probability { q, epoch }) => {
            Response::Probability { epoch, q }
        }
        Ok(QueryReply::TopK { items, epoch }) => Response::TopK { epoch, items },
        Err(message) => Response::Error { code: wire::ERR_SERVE, message },
    }
}

fn handle_connection(shared: &Arc<Shared>, conn_id: u64, stream: UnixStream) {
    // Whatever path exits this handler, drop the registry's stream clone
    // so closed connections release their duplicated fd immediately.
    struct Deregister<'a> {
        shared: &'a Shared,
        conn_id: u64,
    }
    impl Drop for Deregister<'_> {
        fn drop(&mut self) {
            self.shared
                .streams
                .lock()
                .unwrap()
                .retain(|(id, _)| *id != self.conn_id);
        }
    }
    let _deregister = Deregister { shared: shared.as_ref(), conn_id };
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<(u64, Response)>();
    let writer = std::thread::Builder::new()
        .name("rfsm-transport-write".into())
        .spawn(move || writer_loop(writer_stream, &rx));
    let mut reader = BufReader::new(stream);
    loop {
        match wire::read_request(&mut reader) {
            Ok(None) => break, // clean EOF
            Ok(Some((id, request))) => {
                shared.requests.fetch_add(1, Ordering::Relaxed);
                let (h, query) = request.into_query();
                let reply_tx = tx.clone();
                let accepted = shared.batcher.submit(h, query, move |res| {
                    // A closed connection drops the receiver; that is the
                    // client's problem, not the batcher's.
                    let _ = reply_tx.send((id, reply_to_response(res)));
                });
                if !accepted {
                    let _ = tx.send((
                        id,
                        Response::Error {
                            code: wire::ERR_SHUTDOWN,
                            message: "server shutting down".into(),
                        },
                    ));
                    break;
                }
            }
            Err(ProtocolError::Io(_)) => {
                // Dead socket: nothing to answer.
                break;
            }
            Err(e) => {
                // Framing violation (truncated/oversized/bad version or
                // kind/malformed): one typed error frame (request id 0 =
                // connection-level), best-effort since a truncating peer
                // may already be gone, then close. The batcher never saw
                // the bytes, so it cannot be poisoned.
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send((
                    0,
                    Response::Error {
                        code: wire::ERR_PROTOCOL,
                        message: e.to_string(),
                    },
                ));
                break;
            }
        }
    }
    // Dropping our sender lets the writer exit once every in-flight
    // reply (whose callbacks hold clones) has been delivered.
    drop(tx);
    if let Ok(w) = writer {
        let _ = w.join();
    }
}

fn writer_loop(stream: UnixStream, rx: &mpsc::Receiver<(u64, Response)>) {
    let mut w = BufWriter::new(stream);
    'outer: loop {
        let mut item = match rx.recv() {
            Ok(x) => x,
            Err(_) => break,
        };
        // Write everything currently queued, then flush once — batches
        // response frames the same way requests coalesce.
        loop {
            if wire::write_response(&mut w, item.0, &item.1).is_err() {
                break 'outer;
            }
            match rx.try_recv() {
                Ok(next) => item = next,
                Err(_) => break,
            }
        }
        if w.flush().is_err() {
            break;
        }
    }
    let _ = w.flush();
}
