//! L3.5 — the online serving subsystem: kernel-based sampling under live
//! concurrent traffic.
//!
//! The paper's `O(D log n)` per-draw cost makes RF-softmax viable beyond
//! training — for online negative sampling and candidate retrieval — *if*
//! the sampling structure can be read while it is being refreshed (the
//! regime of Blanc & Rendle's adaptive kernel sampling and Chen et al.'s
//! inverted-multi-index variant). This module supplies that concurrency
//! layer on top of the batch-first sampler pipeline:
//!
//! * [`SamplerServer`] / [`SamplerWriter`] (`server.rs`) — epoch-versioned
//!   immutable snapshots behind an O(1) atomic publication. Readers pin a
//!   [`SamplerSnapshot`] via `Arc` and never block on the writer; the
//!   writer applies batched class updates to a private *shadow* sampler
//!   and swaps it in at step boundaries, recycling the retired snapshot
//!   through a replay log instead of rebuilding.
//! * [`MicroBatcher`] (`batcher.rs`) — coalesces concurrently-arriving
//!   `sample` requests (bounded by `serving.max_batch` /
//!   `serving.max_wait_us`) into one `serve_batch` call: a single
//!   `map_batch` gemm plus fanned-out tree walks, so serving throughput
//!   inherits the PR-1 batch amortization. Per-request seeds make served
//!   draws deterministic regardless of coalescing or thread schedule.
//! * [`DoubleBufferedSampler`] (`service.rs`) — the trainer integration:
//!   `update_classes` is staged to a writer thread and overlaps the
//!   step's loss execution; the swap lands before the next draw
//!   (the ROADMAP "async double-buffered tree updates" item).
//! * [`run_closed_loop`] (`loadgen.rs`) — the closed-loop load generator
//!   behind `rfsoftmax serve-bench` and `benches/perf_serving.rs`.
//!
//! Requests served: `sample` (micro-batched), `probability`, and `top_k`
//! (best-first tree search — see `KernelTree::top_k`).
//!
//! Memory: double buffering keeps exactly two full sampler states alive
//! (published + shadow) — the inherent cost of never blocking readers.

mod batcher;
mod loadgen;
mod server;
mod service;

pub use batcher::{BatcherOptions, MicroBatcher, ServeReply};
pub use loadgen::{run_closed_loop, LoadReport, LoadSpec};
pub use server::{SamplerServer, SamplerSnapshot, SamplerWriter};
pub use service::{DoubleBufferedSampler, ServingStats};
