//! L3.5 — the online serving subsystem: kernel-based sampling under live
//! concurrent traffic.
//!
//! The paper's `O(D log n)` per-draw cost makes RF-softmax viable beyond
//! training — for online negative sampling and candidate retrieval — *if*
//! the sampling structure can be read while it is being refreshed (the
//! regime of Blanc & Rendle's adaptive kernel sampling and Chen et al.'s
//! inverted-multi-index variant). This module supplies that concurrency
//! layer on top of the batch-first sampler pipeline:
//!
//! * [`SamplerServer`] / [`SamplerWriter`] (`server.rs`) — epoch-versioned
//!   immutable snapshots behind an O(1) atomic publication. Readers pin a
//!   [`SamplerSnapshot`] via `Arc` and never block on the writer; the
//!   writer applies batched class updates to a private *shadow* sampler
//!   and swaps it in at step boundaries, recycling the retired snapshot
//!   through a replay log instead of rebuilding.
//! * [`MicroBatcher`] (`batcher.rs`) — coalesces concurrently-arriving
//!   requests of *every* kind — `sample`, `probability`, and `top_k` —
//!   (bounded by `serving.max_batch` / `serving.max_wait_us`) into one
//!   `serve_queries` wave: a single `map_batch` gemm regardless of query
//!   kind, plus per-row tree operations fanned out on the persistent
//!   [`crate::exec::serve_pool`] (zero per-batch thread spawns). The
//!   non-blocking [`MicroBatcher::submit`] callback API is what lets the
//!   [`crate::transport`] layer keep many requests per connection in
//!   flight. Per-request seeds make served draws deterministic
//!   regardless of coalescing or thread schedule.
//! * [`DoubleBufferedSampler`] (`service.rs`) — the trainer integration:
//!   `update_classes` is staged to a writer thread and overlaps the
//!   step's loss execution; the swap lands before the next draw
//!   (the ROADMAP "async double-buffered tree updates" item).
//! * [`run_closed_loop`] (`loadgen.rs`) — the closed-loop load generator
//!   behind `rfsoftmax serve-bench` and `benches/perf_serving.rs`;
//!   [`run_cluster_closed_loop`] is its replicated sibling, driving
//!   `--replicas N` in-process shard servers through a
//!   [`crate::cluster::ClusterRouter`] (L5).
//!
//! Requests served (all micro-batched): `sample`, `probability`, and
//! `top_k` (best-first tree search — see `KernelTree::top_k`). For the
//! cross-process wire around this layer see [`crate::transport`] (L4).
//!
//! Memory: double buffering keeps exactly two full sampler states alive
//! (published + shadow) — the inherent cost of never blocking readers.
//!
//! Durability: [`SamplerServer::snapshot_state`] captures the published
//! sampler's full state as a [`crate::snapshot::Snapshot`];
//! [`SamplerWriter::apply_restore`] stages a full-state restore through
//! the same replay log as churn, so a restore becomes visible as one
//! epoch swap and readers never observe partial state. Both are reached
//! uniformly through the [`crate::admin::AdminSurface`] ops on
//! [`DoubleBufferedSampler`] and [`SharedWriterAdmin`].

mod batcher;
mod loadgen;
mod server;
mod service;

pub use batcher::{
    BatcherOptions, BatcherStats, MicroBatcher, QueryReply, ServeReply,
    SubmitReply,
};
pub use loadgen::{
    run_closed_loop, run_cluster_closed_loop, ChurnSpec, LoadReport,
    LoadSpec, RequestMix, SharedWriterAdmin, TransportMode,
};
pub use server::{SamplerServer, SamplerSnapshot, SamplerWriter};
pub use service::{DoubleBufferedSampler, ServingStats};
