//! Closed-loop load generator for the serving subsystem — the engine
//! behind the `serve-bench` CLI subcommand and `benches/perf_serving.rs`.
//!
//! `R` reader threads each issue requests back-to-back (closed loop: a
//! new request is issued only when the previous reply lands) while an
//! optional writer thread applies batched random class updates to the
//! shadow and publishes — the live-traffic regime of the ROADMAP north
//! star. Two transports:
//!
//! * [`TransportMode::Inproc`] — readers call the [`MicroBatcher`]
//!   directly (the PR-2 loop);
//! * [`TransportMode::Uds`] — readers are real
//!   [`crate::transport::TransportClient`] connections to a
//!   [`crate::transport::TransportServer`] on a unix socket, so the
//!   closed loop crosses the wire protocol end to end.
//!
//! * [`TransportMode::Tcp`] — same as uds but over a loopback (or
//!   cross-machine) TCP listener bound at `spec.listen`
//!   (`serving.listen`; port 0 = kernel-assigned), with `TCP_NODELAY`.
//!
//! With `spec.wave > 1` the wire readers switch from one-request-per
//! -frame pipelining to **wire v3 batched waves**: each reader issues
//! its requests as pipelined waves of `wave` sub-requests
//! (`TransportClient::pipeline_waves`), so the server parses one frame
//! header per wave and serves the wave as one coalesced batch. The
//! BENCH record then exposes the header amortization directly:
//! `req_headers_per_request` (server-side frames-parsed / requests) and
//! `resp_headers_per_request` (client side) drop from 1.0 toward
//! `1/wave`.
//!
//! Requests follow a configurable `sample:probability:top_k` mix
//! ([`RequestMix`]). Reports throughput, latency percentiles, coalescing
//! behaviour, swap stalls, per-kind counts, and (for the wire
//! transports) mean frame and wave encode/decode overhead as BENCH JSON.

use super::{BatcherOptions, MicroBatcher, SamplerServer, SamplerWriter};
use crate::admin::{AdminError, AdminOp, AdminResponse, AdminSurface};
use crate::cluster::{
    shard_partition, Cluster, ClusterError, ClusterOptions, ClusterQuery,
};
use crate::json::Json;
use crate::linalg::{simd, unit_vector, Matrix, QuantizeKind};
use crate::metrics::live::{LiveRegistry, Stage};
use crate::rng::Rng;
use crate::sampler::{Sampler, VocabError};
use crate::transport::{wire, ClientFrameStats, TransportClient, TransportServer, VocabAdmin};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Class-universe churn driven during the closed loop (`serve-bench
/// --churn adds:retires[:ops]`): `ops` structural mutations, each an
/// add-batch or retire-batch picked with `adds:retires` weights. Over
/// the uds transport the mutations travel as `ADD_CLASSES` /
/// `RETIRE_CLASSES` admin frames on a dedicated connection; inproc they
/// apply straight through the shared sampler writer. Mutation latency
/// percentiles and post-churn qps land in the BENCH JSON.
#[derive(Clone, Copy, Debug)]
pub struct ChurnSpec {
    /// Relative weight of add mutations.
    pub adds: u32,
    /// Relative weight of retire mutations.
    pub retires: u32,
    /// Total structural mutations to perform.
    pub ops: usize,
    /// Classes added/retired per mutation.
    pub batch: usize,
}

impl ChurnSpec {
    /// Parse `"adds:retires"` or `"adds:retires:ops"` (e.g. `3:1`,
    /// `3:1:500`). Defaults: 200 ops of 8 classes each.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 2 || parts.len() == 3,
            "churn must be adds:retires[:ops], got '{s}'"
        );
        let num = |p: &str| -> anyhow::Result<u32> {
            p.parse()
                .map_err(|_| anyhow::anyhow!("bad churn weight '{p}' in '{s}'"))
        };
        let spec = Self {
            adds: num(parts[0])?,
            retires: num(parts[1])?,
            ops: if parts.len() == 3 { num(parts[2])? as usize } else { 200 },
            batch: 8,
        };
        anyhow::ensure!(
            spec.adds + spec.retires > 0,
            "churn '{s}' has zero total weight"
        );
        Ok(spec)
    }

    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.adds, self.retires, self.ops)
    }
}

/// The immediate-publish [`AdminSurface`] over a shared sampler writer:
/// apply the op to the shadow, publish one epoch-versioned swap, echo
/// the epoch at which it is already visible. This is what
/// [`crate::transport::TransportServer`] routes the admin frames
/// (`ADD_CLASSES`/`RETIRE_CLASSES`/`STATE_SNAPSHOT`) through — exported
/// so any embedder of the transport reuses the same ingestion contract
/// (wire embeddings are row-normalized here: the kernel samplers assume
/// the paper's normalized regime, so a class added over uds lands
/// identically to one added by the trainer).
#[derive(Clone)]
pub struct SharedWriterAdmin {
    writer: Arc<Mutex<SamplerWriter>>,
    dim: usize,
}

impl SharedWriterAdmin {
    /// `dim` is the serving class-embedding width; admin frames with any
    /// other width are rejected per-request.
    pub fn new(writer: Arc<Mutex<SamplerWriter>>, dim: usize) -> Self {
        Self { writer, dim }
    }
}

impl AdminSurface for SharedWriterAdmin {
    fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError> {
        match op {
            AdminOp::AddClasses { embeddings } => {
                if embeddings.cols() != self.dim {
                    return Err(AdminError::Vocab(VocabError(format!(
                        "add_classes: embedding dim {} != serving dim {}",
                        embeddings.cols(),
                        self.dim
                    ))));
                }
                // Same ingestion contract as SamplerService::extend_vocab:
                // the kernel samplers assume the paper's
                // normalized-embedding regime, so raw wire floats are
                // normalized here — a class added over uds and one added
                // by the trainer land identically.
                let mut emb = embeddings;
                emb.normalize_rows_in_place();
                let mut w = self.writer.lock().unwrap();
                let ids = w.apply_add_classes(emb)?;
                let epoch = w.publish();
                Ok(AdminResponse::Added { ids, epoch })
            }
            AdminOp::RetireClasses { ids } => {
                let mut w = self.writer.lock().unwrap();
                w.apply_retire_classes(ids)?;
                Ok(AdminResponse::Retired { epoch: w.publish() })
            }
            AdminOp::Snapshot => {
                let w = self.writer.lock().unwrap();
                let snapshot = w
                    .server()
                    .snapshot_state()
                    .ok_or(AdminError::Unsupported("served sampler kind"))?;
                Ok(AdminResponse::Snapshot { snapshot: Box::new(snapshot) })
            }
            AdminOp::Restore { state } => {
                let mut w = self.writer.lock().unwrap();
                w.apply_restore(Arc::new(*state))?;
                Ok(AdminResponse::Restored { epoch: w.publish() })
            }
        }
    }
}

/// Legacy wire-admin dialect, delegating to the [`AdminSurface`] impl.
impl VocabAdmin for SharedWriterAdmin {
    fn add_classes(
        &self,
        dim: usize,
        rows: usize,
        data: Vec<f32>,
    ) -> Result<(Vec<u32>, u64), String> {
        let emb = Matrix::from_vec(rows, dim, data);
        let mut surface = self.clone();
        surface.admin_add(emb).map_err(|e| e.to_string())
    }

    fn retire_classes(&self, ids: &[u32]) -> Result<u64, String> {
        let mut surface = self.clone();
        surface.admin_retire(ids.to_vec()).map_err(|e| e.to_string())
    }
}

/// Which plumbing the closed loop runs through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportMode {
    /// Readers call the micro-batcher in-process.
    Inproc,
    /// Readers connect over a unix-domain socket and speak the
    /// [`crate::transport::wire`] protocol.
    Uds,
    /// Readers connect over TCP (loopback in the bench; the same
    /// listener serves cross-machine) and speak the identical wire
    /// protocol.
    Tcp,
}

impl TransportMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "inproc" => Ok(TransportMode::Inproc),
            "uds" => Ok(TransportMode::Uds),
            "tcp" => Ok(TransportMode::Tcp),
            _ => anyhow::bail!("unknown transport '{s}' (inproc|uds|tcp)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportMode::Inproc => "inproc",
            TransportMode::Uds => "uds",
            TransportMode::Tcp => "tcp",
        }
    }

    /// Whether this mode runs over the wire protocol (frames exist).
    pub fn is_wire(&self) -> bool {
        !matches!(self, TransportMode::Inproc)
    }
}

/// Relative weights of the three request kinds in the closed loop.
#[derive(Clone, Copy, Debug)]
pub struct RequestMix {
    pub sample: u32,
    pub prob: u32,
    pub topk: u32,
}

impl Default for RequestMix {
    fn default() -> Self {
        Self { sample: 1, prob: 0, topk: 0 }
    }
}

impl RequestMix {
    /// Parse `"sample:prob:topk"` weights, e.g. `8:1:1`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "request mix must be sample:prob:topk, got '{s}'"
        );
        let w: Vec<u32> = parts
            .iter()
            .map(|p| {
                p.parse()
                    .map_err(|_| anyhow::anyhow!("bad mix weight '{p}' in '{s}'"))
            })
            .collect::<anyhow::Result<_>>()?;
        let mix = Self { sample: w[0], prob: w[1], topk: w[2] };
        anyhow::ensure!(
            mix.total() > 0,
            "request mix '{s}' has zero total weight"
        );
        Ok(mix)
    }

    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.sample, self.prob, self.topk)
    }

    fn total(&self) -> u32 {
        self.sample + self.prob + self.topk
    }

    /// Weighted kind pick, deterministic in `rng`.
    fn pick(&self, rng: &mut Rng) -> ReqKind {
        let r = rng.below(self.total() as u64) as u32;
        if r < self.sample {
            ReqKind::Sample
        } else if r < self.sample + self.prob {
            ReqKind::Prob
        } else {
            ReqKind::TopK
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ReqKind {
    Sample,
    Prob,
    TopK,
}

/// Closed-loop run parameters.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// Concurrent reader threads (uds: one connection each).
    pub readers: usize,
    /// Requests issued by each reader.
    pub requests_per_reader: usize,
    /// Negatives per sample request.
    pub m: usize,
    /// k for top_k requests.
    pub top_k: usize,
    /// Query / class-embedding dimension d.
    pub dim: usize,
    /// Base seed for query generation and per-request draw seeds.
    pub seed: u64,
    /// Micro-batcher coalescing bounds.
    pub batcher: BatcherOptions,
    /// Classes updated per writer cycle (0 disables the writer).
    pub updates_per_swap: usize,
    /// Pause between writer cycles (approximates a training-step cadence;
    /// 0 = swap as fast as possible).
    pub swap_pause: Duration,
    /// In-process batcher calls, the unix-socket wire, or TCP.
    pub transport: TransportMode,
    /// sample:prob:topk request mix.
    pub mix: RequestMix,
    /// Optional class-universe churn running alongside the readers.
    pub churn: Option<ChurnSpec>,
    /// Wire-wave size: `1` sends one request per frame; `> 1` packs each
    /// reader's pipelined burst into wire v3 wave frames of this many
    /// sub-requests (wire transports only).
    pub wave: usize,
    /// TCP bind address for [`TransportMode::Tcp`] (config key
    /// `serving.listen`); port 0 asks the kernel for an ephemeral port.
    pub listen: String,
    /// Sampler-embedding quantization the benched sampler was built with
    /// (`sampler.quantize`); recorded verbatim in the BENCH JSON so
    /// f16/i8 cells are distinguishable from f32 runs.
    pub quantize: QuantizeKind,
    /// Keep the transport listening this long after the readers finish
    /// (`serve-bench --hold`). Zero tears down immediately. A non-zero
    /// hold is how CI scrapes a live `STATS` frame: the closed loop
    /// completes, the server stays up with its telemetry intact, and an
    /// external `rfsoftmax stats` client reconciles stage counts against
    /// the request total. Stats in the BENCH record are read *before*
    /// the hold, so scrapes never pollute the frame counters.
    pub hold: Duration,
    /// Serving replicas. `1` is the classic single-node closed loop
    /// ([`run_closed_loop`]); `> 1` spins this many in-process
    /// [`TransportServer`]s — each owning one consistent-hash shard of
    /// the class universe — and drives them through a
    /// [`crate::cluster::ClusterRouter`] ([`run_cluster_closed_loop`]).
    pub replicas: usize,
    /// Enable hedged sub-requests in the cluster path
    /// (`cluster.hedge`): duplicate a straggling replica sub-wave after
    /// a p99-derived delay. Ignored when `replicas == 1`.
    pub hedge: bool,
    /// Consistent-hash ring points per replica
    /// (`cluster.virtual_nodes`). Must match the partition the
    /// per-replica samplers were built over. Ignored when
    /// `replicas == 1`.
    pub virtual_nodes: usize,
    /// Warm-start the serving stack from a durable snapshot
    /// (`serve-bench --restore DIR:NAME`): the sampler passed to
    /// [`run_closed_loop`] is treated as a skeleton (same construction
    /// recipe — the snapshot's feature-map fingerprint must match) and
    /// the captured state is swapped in wholesale before the first
    /// reader starts. Single-node only.
    pub restore: Option<std::sync::Arc<crate::snapshot::Snapshot>>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            readers: 4,
            requests_per_reader: 1000,
            m: 20,
            top_k: 10,
            dim: 64,
            seed: 1,
            batcher: BatcherOptions::default(),
            updates_per_swap: 32,
            swap_pause: Duration::from_micros(200),
            transport: TransportMode::Inproc,
            mix: RequestMix::default(),
            churn: None,
            wave: 1,
            listen: "127.0.0.1:0".into(),
            quantize: QuantizeKind::None,
            hold: Duration::ZERO,
            replicas: 1,
            hedge: false,
            virtual_nodes: 64,
            restore: None,
        }
    }
}

/// What a closed-loop run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub sampler: String,
    pub transport: String,
    pub mix: String,
    pub readers: usize,
    pub requests: u64,
    pub sample_requests: u64,
    pub prob_requests: u64,
    pub topk_requests: u64,
    pub wall_seconds: f64,
    pub qps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub epochs: u64,
    pub swap_stalls: u64,
    /// Mean wall time to encode one request frame of this run's mix into
    /// a reused buffer — the zero-copy production path (µs; 0 for the
    /// inproc transport, which has no frames).
    pub frame_encode_us: f64,
    /// Same encode but into a fresh `Vec` per frame (the pre-zero-copy
    /// behaviour), kept so the delta stays visible in the trajectory.
    pub frame_encode_fresh_us: f64,
    /// Mean wall time to decode one response frame of this run's mix
    /// (µs; 0 for inproc).
    pub frame_decode_us: f64,
    /// Wire-wave size the readers pipelined with (1 = single frames).
    pub wave: usize,
    /// Frames carrying requests the server parsed (singles + waves).
    pub req_frames: u64,
    /// Wave frames among `req_frames`.
    pub wave_frames: u64,
    /// Frames carrying responses the clients parsed (summed over
    /// readers; wave replies pack many responses per frame).
    pub resp_frames: u64,
    /// Per-request header overhead, request direction: frame headers the
    /// server parsed per serve request (1.0 without waves, ≈ `1/wave`
    /// with them; 0 for inproc — no frames exist).
    pub req_headers_per_request: f64,
    /// Per-request header overhead, response direction (client-side
    /// frames parsed / responses received).
    pub resp_headers_per_request: f64,
    /// Mean wall time to encode one whole request wave of `wave`
    /// mixed sub-requests into a reused buffer (µs; 0 when wave ≤ 1 or
    /// inproc).
    pub wave_encode_us: f64,
    /// Mean wall time to decode one whole response wave of `wave`
    /// sub-responses (µs; 0 when wave ≤ 1 or inproc).
    pub wave_decode_us: f64,
    /// Churn label (`adds:retires:ops`; empty when churn is off).
    pub churn: String,
    /// Structural mutations performed (adds + retires).
    pub mutations: u64,
    /// Classes added / retired across the run.
    pub classes_added: u64,
    pub classes_retired: u64,
    /// Mutation latency percentiles (µs; end-to-end over the admin
    /// frames for the uds transport, writer-apply + publish inproc).
    pub mut_p50_us: f64,
    pub mut_p99_us: f64,
    /// Throughput measured over the tail of the run after the last
    /// structural mutation landed (0 when churn is off or nothing
    /// completed afterwards).
    pub post_churn_qps: f64,
    /// Live classes at the end of the run.
    pub live_final: u64,
    /// Sampler-embedding quantization mode (`none` | `f16` | `i8`).
    pub quantize: &'static str,
    /// SIMD dispatch tier the process resolved at startup
    /// (`avx2` | `neon` | `scalar`) — lets BENCH consumers compare runs
    /// across machines and the forced-scalar CI lane honestly.
    pub simd: &'static str,
    /// Per-stage latency breakdown from the live telemetry registry:
    /// `{stage: {count, mean_us, p50_us, p99_us, max_us}}` for decode /
    /// queue_wait / coalesce / gemm_wave / tree_walk / encode_reply.
    /// Stage counts equal served-request counts (batch-shared stages
    /// record each request's share), so BENCH consumers can reconcile
    /// the breakdown against `requests`. Inproc runs have zero decode /
    /// encode_reply counts — those stages live in the transport layer.
    pub stages: Json,
    /// Attributed telemetry cost as a percent of the mean request cost:
    /// measured per-record overhead (enabled minus disabled registry,
    /// tight loop on a scratch registry) × records per request ÷ mean
    /// per-request wall. Machine-checked by `bench-check
    /// --require-telemetry-overhead` (ISSUE 7 budget: ≤ 2%).
    pub telemetry_overhead_pct: f64,
    /// Serving replicas behind the readers (1 = single node; > 1 =
    /// cluster path through the [`crate::cluster::ClusterRouter`]).
    pub replicas: usize,
    /// Worst per-replica replication lag (queued + in-flight log
    /// entries) sampled the moment the readers finished — the
    /// steady-state lag under load, before the final flush converges
    /// it. Always 0 for single-node runs.
    pub repl_lag: u64,
    /// Replication-log entries abandoned on dead replicas across the
    /// run (0 unless a replica died mid-churn).
    pub repl_dropped: u64,
    /// Hedged sub-requests fired / won by the routers (cluster path
    /// with `hedge` enabled; always 0 otherwise).
    pub hedges_fired: u64,
    pub hedges_won: u64,
    /// Replica connections the routers declared dead and failed over
    /// from.
    pub failovers: u64,
}

impl LoadReport {
    /// One human-readable summary line.
    pub fn render(&self) -> String {
        let mut line = format!(
            "{:<14} {:<6} mix={} readers={} qps={:>10.0} p50={:>8.1}µs \
             p99={:>8.1}µs mean_batch={:>5.1} epochs={} swap_stalls={}",
            self.sampler,
            self.transport,
            self.mix,
            self.readers,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.mean_batch,
            self.epochs,
            self.swap_stalls,
        );
        line.push_str(&format!(" tel_ovh={:.3}%", self.telemetry_overhead_pct));
        if self.replicas > 1 {
            line.push_str(&format!(
                " replicas={} lag={} dropped={} failovers={} hedges={}/{}",
                self.replicas,
                self.repl_lag,
                self.repl_dropped,
                self.failovers,
                self.hedges_won,
                self.hedges_fired,
            ));
        }
        if self.wave > 1 {
            line.push_str(&format!(
                " wave={} hdr/req={:.3} hdr/resp={:.3}",
                self.wave,
                self.req_headers_per_request,
                self.resp_headers_per_request,
            ));
        }
        if self.mutations > 0 {
            line.push_str(&format!(
                " churn={} mut_p50={:>7.1}µs mut_p99={:>7.1}µs \
                 post_churn_qps={:>9.0} live={}",
                self.churn,
                self.mut_p50_us,
                self.mut_p99_us,
                self.post_churn_qps,
                self.live_final,
            ));
        }
        line
    }

    /// Machine-readable BENCH record (matches the `perf_hotpath` idiom).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::from("serving_closed_loop")),
            ("sampler", Json::from(self.sampler.as_str())),
            ("transport", Json::from(self.transport.as_str())),
            ("mix", Json::from(self.mix.as_str())),
            ("readers", Json::from(self.readers)),
            ("requests", Json::from(self.requests as usize)),
            ("sample_requests", Json::from(self.sample_requests as usize)),
            ("prob_requests", Json::from(self.prob_requests as usize)),
            ("topk_requests", Json::from(self.topk_requests as usize)),
            ("wall_seconds", Json::from(self.wall_seconds)),
            ("qps", Json::from(self.qps)),
            ("mean_us", Json::from(self.mean_us)),
            ("p50_us", Json::from(self.p50_us)),
            ("p99_us", Json::from(self.p99_us)),
            ("batches", Json::from(self.batches as usize)),
            ("mean_batch", Json::from(self.mean_batch)),
            ("epochs", Json::from(self.epochs as usize)),
            ("swap_stalls", Json::from(self.swap_stalls as usize)),
            ("frame_encode_us", Json::from(self.frame_encode_us)),
            (
                "frame_encode_fresh_us",
                Json::from(self.frame_encode_fresh_us),
            ),
            ("frame_decode_us", Json::from(self.frame_decode_us)),
            ("wave", Json::from(self.wave)),
            ("req_frames", Json::from(self.req_frames as usize)),
            ("wave_frames", Json::from(self.wave_frames as usize)),
            ("resp_frames", Json::from(self.resp_frames as usize)),
            (
                "req_headers_per_request",
                Json::from(self.req_headers_per_request),
            ),
            (
                "resp_headers_per_request",
                Json::from(self.resp_headers_per_request),
            ),
            ("wave_encode_us", Json::from(self.wave_encode_us)),
            ("wave_decode_us", Json::from(self.wave_decode_us)),
            ("churn", Json::from(self.churn.as_str())),
            ("mutations", Json::from(self.mutations as usize)),
            ("classes_added", Json::from(self.classes_added as usize)),
            ("classes_retired", Json::from(self.classes_retired as usize)),
            ("mut_p50_us", Json::from(self.mut_p50_us)),
            ("mut_p99_us", Json::from(self.mut_p99_us)),
            ("post_churn_qps", Json::from(self.post_churn_qps)),
            ("live_final", Json::from(self.live_final as usize)),
            ("quantize", Json::from(self.quantize)),
            ("simd", Json::from(self.simd)),
            ("stages", self.stages.clone()),
            ("telemetry_overhead_pct", Json::from(self.telemetry_overhead_pct)),
            ("replicas", Json::from(self.replicas)),
            ("repl_lag", Json::from(self.repl_lag as usize)),
            ("repl_dropped", Json::from(self.repl_dropped as usize)),
            ("hedges_fired", Json::from(self.hedges_fired as usize)),
            ("hedges_won", Json::from(self.hedges_won as usize)),
            ("failovers", Json::from(self.failovers as usize)),
        ])
    }
}

/// Per-reader issuing backend: direct batcher calls or a wire client
/// (uds and tcp issue identically — the client is socket-agnostic).
enum Issuer<'a> {
    Inproc(&'a MicroBatcher),
    Wire(TransportClient),
}

impl Issuer<'_> {
    /// Issue one request; returns a value to black-box so the draw is
    /// not optimized away.
    fn issue(
        &mut self,
        kind: ReqKind,
        h: &[f32],
        m: usize,
        k: usize,
        class: usize,
        seed: u64,
    ) -> usize {
        match self {
            Issuer::Inproc(b) => match kind {
                ReqKind::Sample => b.sample(h, m, seed).draw.len(),
                ReqKind::Prob => {
                    let (q, _) = b.probability(h, class);
                    q.is_finite() as usize
                }
                ReqKind::TopK => b.top_k(h, k).0.len(),
            },
            Issuer::Wire(c) => match kind {
                ReqKind::Sample => c
                    .sample(h, m, seed)
                    .expect("wire sample request failed")
                    .draw
                    .len(),
                ReqKind::Prob => {
                    let (q, _) = c
                        .probability(h, class)
                        .expect("wire probability request failed");
                    q.is_finite() as usize
                }
                ReqKind::TopK => {
                    c.top_k(h, k).expect("wire top_k request failed").0.len()
                }
            },
        }
    }

    /// Client frame counters, for the response-direction header
    /// overhead (zeros for the in-process issuer).
    fn frame_stats(&self) -> ClientFrameStats {
        match self {
            Issuer::Inproc(_) => ClientFrameStats::default(),
            Issuer::Wire(c) => c.frame_stats(),
        }
    }
}

/// Mean per-frame encode/decode wall time (µs) for this run's request
/// mix, measured on in-memory buffers — the wire protocol's CPU overhead
/// isolated from socket latency. Returns `(encode_reused,
/// encode_fresh, decode)`: the reused-buffer encode is the zero-copy
/// production path, the fresh-`Vec` encode is kept as the baseline so
/// the saving stays visible in `frame_encode_us` vs
/// `frame_encode_fresh_us`. Response decode uses representative reply
/// shapes (m draws / a top-k list / one probability).
fn measure_codec_overhead(spec: &LoadSpec) -> (f64, f64, f64) {
    let kinds: Vec<(ReqKind, u32)> = [
        (ReqKind::Sample, spec.mix.sample),
        (ReqKind::Prob, spec.mix.prob),
        (ReqKind::TopK, spec.mix.topk),
    ]
    .into_iter()
    .filter(|(_, w)| *w > 0)
    .collect();
    let mut rng = Rng::seeded(spec.seed ^ 0xC0DE);
    let h = unit_vector(&mut rng, spec.dim);
    let reps = 2000usize;
    let mut encode_us = 0.0;
    let mut encode_fresh_us = 0.0;
    let mut decode_us = 0.0;
    let total_w: u32 = kinds.iter().map(|(_, w)| w).sum();
    for (kind, w) in &kinds {
        let req = match kind {
            ReqKind::Sample => {
                wire::Request::Sample { h: h.clone(), m: spec.m as u32, seed: 7 }
            }
            ReqKind::Prob => wire::Request::Probability { h: h.clone(), class: 0 },
            ReqKind::TopK => {
                wire::Request::TopK { h: h.clone(), k: spec.top_k as u32 }
            }
        };
        let resp = match kind {
            ReqKind::Sample => wire::Response::Sample {
                epoch: 1,
                ids: (0..spec.m as u32).collect(),
                probs: vec![1e-4; spec.m],
            },
            ReqKind::Prob => wire::Response::Probability { epoch: 1, q: 1e-4 },
            ReqKind::TopK => wire::Response::TopK {
                epoch: 1,
                items: (0..spec.top_k as u32).map(|i| (i, 1e-4)).collect(),
            },
        };
        // Zero-copy path: one reused buffer, cleared per frame.
        let mut reused = Vec::with_capacity(4 * 1024);
        let t0 = Instant::now();
        let mut sink = 0usize;
        for i in 0..reps {
            reused.clear();
            wire::encode_request(&mut reused, i as u64, &req);
            sink += reused.len();
        }
        let enc = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
        std::hint::black_box(sink);
        // Baseline: a fresh allocation per frame.
        let t0 = Instant::now();
        let mut sink = 0usize;
        for i in 0..reps {
            let mut buf = Vec::new();
            wire::encode_request(&mut buf, i as u64, &req);
            sink += std::hint::black_box(buf).len();
        }
        let enc_fresh = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
        std::hint::black_box(sink);
        let mut buf = Vec::new();
        wire::encode_response(&mut buf, 1, &resp);
        let t0 = Instant::now();
        let mut sink = 0usize;
        for _ in 0..reps {
            let decoded = wire::read_response(&mut &buf[..])
                .expect("codec self-decode")
                .expect("non-empty");
            sink += decoded.0 as usize;
        }
        let dec = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
        std::hint::black_box(sink);
        let frac = *w as f64 / total_w as f64;
        encode_us += frac * enc;
        encode_fresh_us += frac * enc_fresh;
        decode_us += frac * dec;
    }
    (encode_us, encode_fresh_us, decode_us)
}

/// Mean per-wave encode/decode wall time (µs) for wire v3 waves of
/// `spec.wave` requests drawn from this run's mix, measured on
/// in-memory buffers — the wave codec's CPU overhead isolated from
/// socket latency. Returns `(wave_encode_us, wave_decode_us)`; zeros
/// when `spec.wave <= 1` (no waves on the wire). Note these are
/// per-*wave* costs: the per-request share is `wave_encode_us /
/// wave`, directly comparable against `frame_encode_us`.
fn measure_wave_overhead(spec: &LoadSpec) -> (f64, f64) {
    if spec.wave <= 1 {
        return (0.0, 0.0);
    }
    let mut rng = Rng::seeded(spec.seed ^ 0x3A4E);
    let h = unit_vector(&mut rng, spec.dim);
    // One representative request wave of the run's mix.
    let reqs: Vec<wire::Request> = (0..spec.wave)
        .map(|i| match spec.mix.pick(&mut rng) {
            ReqKind::Sample => wire::Request::Sample {
                h: h.clone(),
                m: spec.m as u32,
                seed: i as u64,
            },
            ReqKind::Prob => {
                wire::Request::Probability { h: h.clone(), class: 0 }
            }
            ReqKind::TopK => {
                wire::Request::TopK { h: h.clone(), k: spec.top_k as u32 }
            }
        })
        .collect();
    let items: Vec<(u64, &wire::Request)> =
        reqs.iter().enumerate().map(|(i, r)| (i as u64, r)).collect();
    let reps = 500usize;
    let mut buf: Vec<u8> = Vec::with_capacity(64 * 1024);
    let t0 = Instant::now();
    let mut sink = 0usize;
    for _ in 0..reps {
        buf.clear();
        wire::encode_request_wave(&mut buf, &items);
        sink += buf.len();
    }
    let enc = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
    std::hint::black_box(sink);
    // A response wave of the same depth, with representative sample
    // replies (the mix's dominant kind under the default weights).
    let resps: Vec<(u64, wire::Response)> = (0..spec.wave)
        .map(|i| {
            (
                i as u64,
                wire::Response::Sample {
                    epoch: 1,
                    ids: (0..spec.m as u32).collect(),
                    probs: vec![1e-4; spec.m],
                },
            )
        })
        .collect();
    let mut rbuf = Vec::new();
    wire::encode_response_wave(&mut rbuf, &resps);
    let t0 = Instant::now();
    let mut sink = 0usize;
    for _ in 0..reps {
        let frame = wire::read_response_frame(&mut &rbuf[..])
            .expect("wave self-decode")
            .expect("non-empty");
        if let wire::ResponseFrame::Wave(subs) = frame {
            sink += subs.len();
        }
    }
    let dec = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
    std::hint::black_box(sink);
    (enc, dec)
}

/// How many telemetry points one served request records end to end on
/// the wire path: six stage histogram records (decode, queue_wait,
/// coalesce, gemm_wave, tree_walk, encode_reply), one slow-log offer,
/// and roughly one sharded-counter bump of per-request accounting.
const TELEMETRY_RECORDS_PER_REQUEST: f64 = 8.0;

/// Attributed telemetry overhead as a percent of the mean per-request
/// cost. Measured directly rather than inferred from qps deltas (which
/// drown in scheduler noise at smoke sizes): a tight loop prices one
/// histogram record on a *scratch* registry — enabled minus disabled,
/// so the price is the atomics, not the call — and the per-request
/// telemetry bill is that price × [`TELEMETRY_RECORDS_PER_REQUEST`].
/// The scratch registry keeps the measurement loop's fake records out
/// of the run's real stage histograms (a live `STATS` scrape must
/// still reconcile counts against the request total).
fn measure_telemetry_overhead(mean_request_ns: f64) -> f64 {
    if mean_request_ns <= 0.0 {
        return 0.0;
    }
    let scratch = LiveRegistry::new();
    let reps: u64 = 200_000;
    let mut per_record = [0.0f64; 2];
    for (slot, enabled) in [(0usize, true), (1usize, false)] {
        scratch.set_enabled(enabled);
        let t0 = Instant::now();
        for i in 0..reps {
            scratch.record_stage_ns(Stage::GemmWave, (i & 1023) + 1);
        }
        per_record[slot] = t0.elapsed().as_nanos() as f64 / reps as f64;
    }
    let per_request = (per_record[0] - per_record[1]).max(0.0) * TELEMETRY_RECORDS_PER_REQUEST;
    per_request / mean_request_ns * 100.0
}

/// A unix-socket path unique per process AND per call: two concurrent
/// closed loops with equal seeds must never bind the same path (bind
/// replaces the file, stranding the first server's listener).
fn unique_uds_path(seed: u64) -> std::path::PathBuf {
    static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "rfsm-serve-{}-{}-{}.sock",
        std::process::id(),
        seed,
        SOCK_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Run one closed-loop load test against a fork of `sampler`. The
/// sampler must support serving forks and its class-embedding dimension
/// must equal `spec.dim` (writer updates are drawn at that width).
pub fn run_closed_loop(
    sampler: &dyn Sampler,
    spec: &LoadSpec,
) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(spec.readers >= 1, "serve load: need ≥ 1 reader");
    anyhow::ensure!(spec.m >= 1, "serve load: need m ≥ 1");
    anyhow::ensure!(spec.top_k >= 1, "serve load: need top_k ≥ 1");
    anyhow::ensure!(spec.mix.total() > 0, "serve load: empty request mix");
    anyhow::ensure!(spec.wave >= 1, "serve load: need wave ≥ 1");
    anyhow::ensure!(
        spec.wave == 1 || spec.transport.is_wire(),
        "serve load: --wave needs a wire transport (uds|tcp)"
    );
    anyhow::ensure!(
        spec.replicas <= 1,
        "serve load: replicas > 1 takes the cluster path \
         (run_cluster_closed_loop)"
    );
    let serve = sampler.fork().ok_or_else(|| {
        anyhow::anyhow!(
            "sampler '{}' does not support serving forks",
            sampler.name()
        )
    })?;
    let name = serve.name().to_string();
    let dim = spec.dim;
    let (server, mut writer) = SamplerServer::new(serve);
    // Warm start: swap the snapshot state into the skeleton before any
    // reader (or the writer loop) sees the stack — the restored epoch
    // is published as one ordinary swap, so the run begins exactly
    // where the snapshotted server left off.
    if let Some(snap) = &spec.restore {
        writer
            .apply_restore(Arc::new(snap.state.clone()))
            .map_err(|e| anyhow::anyhow!("serve load: restore: {e}"))?;
        writer.publish();
    }
    let num_classes = server.snapshot().sampler().num_classes();
    let writer = Arc::new(Mutex::new(writer));
    let batcher = Arc::new(MicroBatcher::spawn(server.clone(), spec.batcher));
    let stop = Arc::new(AtomicBool::new(false));
    // Requests completed so far (all readers) — the churn driver
    // snapshots it when its last mutation lands, so post-churn qps can
    // be computed from the tail of the run.
    let completed = Arc::new(AtomicU64::new(0));

    // The wire transports wrap the same batcher behind a socket, with
    // the admin surface routed through the shared sampler writer so
    // ADD_CLASSES/RETIRE_CLASSES/STATE_SNAPSHOT frames work
    // cross-process.
    let transport = match spec.transport {
        TransportMode::Inproc => None,
        TransportMode::Uds => {
            let path = unique_uds_path(spec.seed);
            let admin = Arc::new(Mutex::new(SharedWriterAdmin::new(
                Arc::clone(&writer),
                dim,
            )));
            Some(
                TransportServer::bind_with_surface(
                    &path,
                    Arc::clone(&batcher),
                    admin,
                )
                .map_err(|e| anyhow::anyhow!("bind {path:?}: {e}"))?,
            )
        }
        TransportMode::Tcp => {
            let admin = Arc::new(Mutex::new(SharedWriterAdmin::new(
                Arc::clone(&writer),
                dim,
            )));
            Some(
                TransportServer::bind_tcp_with_surface(
                    &spec.listen,
                    Arc::clone(&batcher),
                    admin,
                )
                .map_err(|e| {
                    anyhow::anyhow!("bind tcp {}: {e}", spec.listen)
                })?,
            )
        }
    };

    // Driver: apply batches of random class updates (publishing each),
    // and — when churn is configured — interleave structural mutations,
    // timing each one. A single driver owns the live-id pool, so update
    // picks can never race a retire.
    struct ChurnOut {
        latencies_ns: Vec<u64>,
        adds: u64,
        retires: u64,
        churn_done: Option<(Instant, u64)>,
    }
    let driver_handle = if spec.updates_per_swap > 0 || spec.churn.is_some() {
        let stop = Arc::clone(&stop);
        let writer = Arc::clone(&writer);
        let completed = Arc::clone(&completed);
        let endpoint = transport.as_ref().map(|t| t.endpoint().clone());
        let churn = spec.churn;
        let updates_per_swap = spec.updates_per_swap;
        let pause = spec.swap_pause;
        let seed = spec.seed ^ 0x57A9_0000_0000_0000;
        Some(std::thread::spawn(move || {
            let mut rng = Rng::seeded(seed);
            // Admin connection for cross-process churn (wire transports).
            let mut admin_client = match (&churn, &endpoint) {
                (Some(_), Some(ep)) => Some(
                    TransportClient::connect_endpoint(ep)
                        .expect("connect admin endpoint"),
                ),
                _ => None,
            };
            // The driver's view of the universe: live ids, never below
            // the floor (readers keep sampling m draws throughout).
            let mut live: Vec<u32> = (0..num_classes as u32).collect();
            let floor = (num_classes / 2).max(2);
            let mut out = ChurnOut {
                latencies_ns: Vec::new(),
                adds: 0,
                retires: 0,
                churn_done: None,
            };
            let mut ops_left = churn.map_or(0, |c| c.ops);
            loop {
                if stop.load(Ordering::Relaxed) && ops_left == 0 {
                    break;
                }
                // Embedding-update churn (the PR-2 writer loop).
                if updates_per_swap > 0 {
                    let k = updates_per_swap.min(live.len());
                    let ids: Vec<u32> = rng
                        .sample_distinct(live.len(), k)
                        .into_iter()
                        .map(|i| live[i])
                        .collect();
                    let mut emb = Matrix::zeros(k, dim);
                    for r in 0..k {
                        let v = unit_vector(&mut rng, dim);
                        emb.row_mut(r).copy_from_slice(&v);
                    }
                    let mut w = writer.lock().unwrap();
                    w.apply_updates(ids, emb);
                    w.publish();
                }
                // Structural churn.
                if ops_left > 0 {
                    let c = churn.expect("ops_left > 0 without churn");
                    let retire_ok = live.len() >= floor + c.batch;
                    if !retire_ok && c.adds == 0 {
                        // Pure-retire churn hit the live floor: stop
                        // early rather than shrink the serving set away.
                        ops_left = 0;
                        out.churn_done = Some((
                            Instant::now(),
                            completed.load(Ordering::Relaxed),
                        ));
                        continue;
                    }
                    let want_add = c.retires == 0
                        || (c.adds > 0
                            && rng.below((c.adds + c.retires) as u64)
                                < c.adds as u64);
                    // Payloads are built BEFORE the latency timer starts,
                    // so mut_p50/p99 measure the mutation (writer apply +
                    // publish, or the admin-frame round trip) and nothing
                    // else.
                    if want_add || !retire_ok {
                        let mut emb = Matrix::zeros(c.batch, dim);
                        for r in 0..c.batch {
                            let v = unit_vector(&mut rng, dim);
                            emb.row_mut(r).copy_from_slice(&v);
                        }
                        let t0 = Instant::now();
                        let ids = match &mut admin_client {
                            Some(cl) => {
                                cl.add_classes(&emb)
                                    .expect("admin add_classes failed")
                                    .0
                            }
                            None => {
                                let mut w = writer.lock().unwrap();
                                let ids = w
                                    .apply_add_classes(emb)
                                    .expect("add_classes failed");
                                w.publish();
                                ids
                            }
                        };
                        out.latencies_ns
                            .push(t0.elapsed().as_nanos() as u64);
                        live.extend_from_slice(&ids);
                        out.adds += c.batch as u64;
                    } else {
                        let victims: Vec<u32> = rng
                            .sample_distinct(live.len(), c.batch)
                            .into_iter()
                            .map(|i| live[i])
                            .collect();
                        let t0 = Instant::now();
                        match &mut admin_client {
                            Some(cl) => {
                                cl.retire_classes(&victims)
                                    .expect("admin retire_classes failed");
                            }
                            None => {
                                let mut w = writer.lock().unwrap();
                                w.apply_retire_classes(victims.clone())
                                    .expect("retire_classes failed");
                                w.publish();
                            }
                        }
                        out.latencies_ns
                            .push(t0.elapsed().as_nanos() as u64);
                        live.retain(|id| !victims.contains(id));
                        out.retires += c.batch as u64;
                    }
                    ops_left -= 1;
                    if ops_left == 0 {
                        out.churn_done = Some((
                            Instant::now(),
                            completed.load(Ordering::Relaxed),
                        ));
                    }
                } else if stop.load(Ordering::Relaxed) {
                    break;
                }
                if !pause.is_zero() {
                    std::thread::sleep(pause);
                }
            }
            out
        }))
    } else {
        None
    };

    // Closed-loop readers. With `wave == 1` each reader is a classic
    // one-request-at-a-time closed loop (latency = per request); with
    // `wave > 1` each reader issues pipelined wire waves of `wave`
    // requests and the latency samples are per *wave* — the unit a
    // wave-batched client actually waits on.
    let t0 = Instant::now();
    type ReaderOut = (Vec<u64>, [u64; 3], ClientFrameStats);
    let reader_out: Vec<ReaderOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..spec.readers)
            .map(|r| {
                let batcher = Arc::clone(&batcher);
                let completed = Arc::clone(&completed);
                let endpoint =
                    transport.as_ref().map(|t| t.endpoint().clone());
                scope.spawn(move || {
                    let mut issuer = match &endpoint {
                        None => Issuer::Inproc(&batcher),
                        Some(ep) => Issuer::Wire(
                            TransportClient::connect_endpoint(ep)
                                .expect("connect serve endpoint"),
                        ),
                    };
                    let mut rng = Rng::seeded(
                        spec.seed
                            .wrapping_add((r as u64).wrapping_mul(0x9E37_79B9)),
                    );
                    let mut lat = Vec::with_capacity(spec.requests_per_reader);
                    let mut counts = [0u64; 3];
                    if spec.wave > 1 {
                        let Issuer::Wire(client) = &mut issuer else {
                            unreachable!("wave > 1 is wire-only (validated)")
                        };
                        let mut left = spec.requests_per_reader;
                        while left > 0 {
                            let w = spec.wave.min(left);
                            left -= w;
                            let mut kinds = Vec::with_capacity(w);
                            let reqs: Vec<wire::Request> = (0..w)
                                .map(|_| {
                                    let kind = spec.mix.pick(&mut rng);
                                    kinds.push(kind);
                                    let h = unit_vector(&mut rng, dim);
                                    match kind {
                                        ReqKind::Sample => {
                                            wire::Request::Sample {
                                                h,
                                                m: spec.m as u32,
                                                seed: rng.next_u64(),
                                            }
                                        }
                                        ReqKind::Prob => {
                                            wire::Request::Probability {
                                                h,
                                                class: rng.index(num_classes)
                                                    as u32,
                                            }
                                        }
                                        ReqKind::TopK => wire::Request::TopK {
                                            h,
                                            k: spec.top_k as u32,
                                        },
                                    }
                                })
                                .collect();
                            let t = Instant::now();
                            let resps = client
                                .pipeline_waves(&reqs, w)
                                .expect("wave pipeline failed");
                            lat.push(t.elapsed().as_nanos() as u64);
                            completed.fetch_add(w as u64, Ordering::Relaxed);
                            debug_assert_eq!(resps.len(), w);
                            for (kind, resp) in kinds.iter().zip(&resps) {
                                if let wire::Response::Error {
                                    code,
                                    message,
                                } = resp
                                {
                                    panic!(
                                        "wave sub-request failed \
                                         (code {code}): {message}"
                                    );
                                }
                                std::hint::black_box(resp);
                                counts[match kind {
                                    ReqKind::Sample => 0,
                                    ReqKind::Prob => 1,
                                    ReqKind::TopK => 2,
                                }] += 1;
                            }
                        }
                    } else {
                        for _ in 0..spec.requests_per_reader {
                            let kind = spec.mix.pick(&mut rng);
                            let h = unit_vector(&mut rng, dim);
                            let seed = rng.next_u64();
                            let class = rng.index(num_classes);
                            let t = Instant::now();
                            let out = issuer.issue(
                                kind, &h, spec.m, spec.top_k, class, seed,
                            );
                            lat.push(t.elapsed().as_nanos() as u64);
                            completed.fetch_add(1, Ordering::Relaxed);
                            std::hint::black_box(out);
                            counts[match kind {
                                ReqKind::Sample => 0,
                                ReqKind::Prob => 1,
                                ReqKind::TopK => 2,
                            }] += 1;
                        }
                    }
                    (lat, counts, issuer.frame_stats())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let run_end = Instant::now();
    stop.store(true, Ordering::Relaxed);
    let churn_out = match driver_handle {
        // A dead driver means the run served a frozen snapshot — report
        // an error, not a healthy-looking BENCH record.
        Some(h) => Some(h.join().map_err(|_| {
            anyhow::anyhow!(
                "serve load: driver thread panicked (LoadSpec.dim mismatch \
                 with the sampler's class-embedding dimension?)"
            )
        })?),
        None => None,
    };
    let live_final = server.snapshot().sampler().live_classes() as u64;
    // Server-side frame counters and the per-stage telemetry breakdown
    // must be read before the transport is dropped (its shutdown joins
    // every connection) — and before any `--hold` scrapes can add
    // admin frames or encode_reply records of their own.
    let wire_stats = transport.as_ref().map(|t| t.stats());
    let stages = batcher.telemetry().stages_json();
    // Keep the server scrapeable after the load completes: CI's
    // live-scrape step reconciles an external `rfsoftmax stats` read
    // against this run's request total during the hold window.
    if !spec.hold.is_zero() && transport.is_some() {
        std::thread::sleep(spec.hold);
    }
    drop(transport); // joins connection threads, removes the socket file

    let mut all: Vec<u64> = Vec::new();
    let mut kind_counts = [0u64; 3];
    let mut resp_frames = 0u64;
    let mut resp_items = 0u64;
    for (lat, counts, fs) in reader_out {
        all.extend(lat);
        for (acc, c) in kind_counts.iter_mut().zip(counts) {
            *acc += c;
        }
        resp_frames += fs.resp_frames;
        resp_items += fs.resp_items;
    }
    all.sort_unstable();
    let pct = |q: f64| -> f64 {
        if all.is_empty() {
            return 0.0;
        }
        all[((all.len() - 1) as f64 * q).round() as usize] as f64 / 1000.0
    };
    // One latency sample per request (wave == 1) or per wave (wave > 1);
    // the request count is the per-kind sum either way.
    let requests = kind_counts.iter().sum::<u64>();
    let mean_us = if all.is_empty() {
        0.0
    } else {
        all.iter().sum::<u64>() as f64 / all.len() as f64 / 1000.0
    };
    let bstats = batcher.stats();
    debug_assert_eq!(bstats.requests, requests);
    let batches = bstats.batches;
    // Latency samples are per wave when wave > 1; the overhead budget
    // is per request, so normalize the denominator first.
    let mean_request_ns = mean_us * 1000.0 / spec.wave.max(1) as f64;
    let telemetry_overhead_pct = measure_telemetry_overhead(mean_request_ns);
    let (frame_encode_us, frame_encode_fresh_us, frame_decode_us) =
        if spec.transport.is_wire() {
            measure_codec_overhead(spec)
        } else {
            (0.0, 0.0, 0.0)
        };
    let (wave_encode_us, wave_decode_us) = if spec.transport.is_wire() {
        measure_wave_overhead(spec)
    } else {
        (0.0, 0.0)
    };
    // Per-request header overhead on both wire directions. The request
    // side is deterministic (readers send ceil(requests/wave) frames
    // each); the response side depends on how many replies the server's
    // writer packed per drain. The driver's admin connection adds its
    // frames to `req_frames` — negligible next to the reader volume, and
    // honest: those headers were parsed too.
    let req_frames = wire_stats.map_or(0, |s| s.request_frames);
    let wave_frames = wire_stats.map_or(0, |s| s.wave_frames);
    let req_headers_per_request = if requests > 0 && spec.transport.is_wire() {
        req_frames as f64 / requests as f64
    } else {
        0.0
    };
    let resp_headers_per_request = if resp_items > 0 {
        resp_frames as f64 / resp_items as f64
    } else {
        0.0
    };
    // Mutation latency percentiles + the post-churn tail throughput.
    let (mutations, adds, retires, mut_p50_us, mut_p99_us, post_churn_qps) =
        match churn_out {
            Some(mut c) if !c.latencies_ns.is_empty() => {
                c.latencies_ns.sort_unstable();
                let mpct = |q: f64| -> f64 {
                    c.latencies_ns
                        [((c.latencies_ns.len() - 1) as f64 * q).round() as usize]
                        as f64
                        / 1000.0
                };
                let tail_qps = match c.churn_done {
                    Some((at, done_count)) => {
                        let tail_secs =
                            run_end.saturating_duration_since(at).as_secs_f64();
                        let tail_reqs =
                            requests.saturating_sub(done_count) as f64;
                        if tail_secs > 0.0 { tail_reqs / tail_secs } else { 0.0 }
                    }
                    None => 0.0,
                };
                (
                    c.latencies_ns.len() as u64,
                    c.adds,
                    c.retires,
                    mpct(0.50),
                    mpct(0.99),
                    tail_qps,
                )
            }
            _ => (0, 0, 0, 0.0, 0.0, 0.0),
        };
    Ok(LoadReport {
        sampler: name,
        transport: spec.transport.name().to_string(),
        mix: spec.mix.label(),
        readers: spec.readers,
        requests,
        sample_requests: kind_counts[0],
        prob_requests: kind_counts[1],
        topk_requests: kind_counts[2],
        wall_seconds: wall,
        qps: requests as f64 / wall.max(1e-12),
        mean_us,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        batches,
        mean_batch: requests as f64 / (batches.max(1)) as f64,
        epochs: server.epoch(),
        swap_stalls: server.swap_stalls(),
        frame_encode_us,
        frame_encode_fresh_us,
        frame_decode_us,
        wave: spec.wave,
        req_frames,
        wave_frames,
        resp_frames,
        req_headers_per_request,
        resp_headers_per_request,
        wave_encode_us,
        wave_decode_us,
        churn: spec.churn.map(|c| c.label()).unwrap_or_default(),
        mutations,
        classes_added: adds,
        classes_retired: retires,
        mut_p50_us,
        mut_p99_us,
        post_churn_qps,
        live_final,
        quantize: spec.quantize.name(),
        simd: simd::tier_name(),
        stages,
        telemetry_overhead_pct,
        replicas: 1,
        repl_lag: 0,
        repl_dropped: 0,
        hedges_fired: 0,
        hedges_won: 0,
        failovers: 0,
    })
}

/// One in-process serving replica of the cluster closed loop: its own
/// snapshot server, micro-batcher, and wire transport over one
/// consistent-hash shard of the class universe.
struct ClusterNode {
    server: SamplerServer,
    batcher: Arc<MicroBatcher>,
    transport: TransportServer,
}

/// Run one closed-loop load test against `spec.replicas` in-process
/// serving replicas behind a [`crate::cluster::ClusterRouter`] — the
/// engine behind `serve-bench --replicas N`.
///
/// `samplers[r]` must be built over exactly the classes of
/// [`shard_partition`]`(n, replicas, virtual_nodes)[r]` **in order** (n
/// = the summed class count); each replica serves its shard and the
/// routers merge answers back into the global id space. Readers issue
/// bursts of `spec.wave` logical requests through
/// [`crate::cluster::ClusterRouter::query_burst`]; churn flows through
/// the epoch-sequenced replication log (so `mut_p50/p99` time the
/// **log append** — owner replicas converge asynchronously, and the
/// run flushes the log before reporting). Differences from the
/// single-node report: `mean_batch`/`batches` are server-side over all
/// replicas (a logical sample fans out, and every burst pays a `MASS`
/// round, so server-side requests exceed logical `requests`);
/// `req_headers_per_request` counts those extra frames too;
/// `resp_frames`/`resp_headers_per_request` are 0 (the routers'
/// internal client connections are not instrumented); `stages` is
/// replica 0's breakdown, representative under the ring's near-uniform
/// shard balance; the embedding-update writer loop is single-node-only
/// (no update admin frame exists), so `updates_per_swap` is ignored.
pub fn run_cluster_closed_loop(
    samplers: &[Box<dyn Sampler>],
    spec: &LoadSpec,
) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(spec.replicas >= 2, "cluster load: need replicas ≥ 2");
    anyhow::ensure!(
        samplers.len() == spec.replicas,
        "cluster load: {} samplers for {} replicas",
        samplers.len(),
        spec.replicas
    );
    anyhow::ensure!(
        spec.transport.is_wire(),
        "cluster load: --replicas needs a wire transport (uds|tcp)"
    );
    anyhow::ensure!(spec.readers >= 1, "cluster load: need ≥ 1 reader");
    anyhow::ensure!(spec.m >= 1, "cluster load: need m ≥ 1");
    anyhow::ensure!(spec.top_k >= 1, "cluster load: need top_k ≥ 1");
    anyhow::ensure!(spec.mix.total() > 0, "cluster load: empty request mix");
    anyhow::ensure!(
        spec.restore.is_none(),
        "cluster load: --restore is single-node (per-replica snapshots \
         are fetched and restored through Cluster::bootstrap_replica)"
    );
    anyhow::ensure!(
        spec.wave >= 1 && spec.wave <= crate::transport::MAX_IN_FLIGHT / 2,
        "cluster load: wave must be in 1..={} (burst sub-batches must \
         stay under the server's in-flight shed cap)",
        crate::transport::MAX_IN_FLIGHT / 2
    );
    let n: usize = samplers.iter().map(|s| s.num_classes()).sum();
    let partitions = shard_partition(n, spec.replicas, spec.virtual_nodes);
    for (r, (p, s)) in partitions.iter().zip(samplers).enumerate() {
        anyhow::ensure!(
            p.len() == s.num_classes(),
            "cluster load: replica {r} sampler holds {} classes but its \
             ring shard holds {} — build each replica's sampler over \
             shard_partition(n, replicas, virtual_nodes)[{r}]",
            s.num_classes(),
            p.len()
        );
    }
    let dim = spec.dim;
    let name = samplers[0].name().to_string();

    let mut nodes = Vec::with_capacity(spec.replicas);
    let mut endpoints = Vec::with_capacity(spec.replicas);
    for (r, sampler) in samplers.iter().enumerate() {
        let serve = sampler.fork().ok_or_else(|| {
            anyhow::anyhow!(
                "sampler '{}' does not support serving forks",
                sampler.name()
            )
        })?;
        let (server, writer) = SamplerServer::new(serve);
        let writer = Arc::new(Mutex::new(writer));
        let batcher = Arc::new(MicroBatcher::spawn(server.clone(), spec.batcher));
        let admin = Arc::new(Mutex::new(SharedWriterAdmin::new(
            Arc::clone(&writer),
            dim,
        )));
        let transport = match spec.transport {
            TransportMode::Inproc => unreachable!("validated wire-only"),
            TransportMode::Uds => {
                let path = unique_uds_path(spec.seed);
                TransportServer::bind_with_surface(
                    &path,
                    Arc::clone(&batcher),
                    admin,
                )
                .map_err(|e| {
                    anyhow::anyhow!("replica {r}: bind {path:?}: {e}")
                })?
            }
            TransportMode::Tcp => {
                // Every replica needs its own port, so the in-process
                // cluster always asks the kernel (spec.listen would
                // collide past the first replica).
                TransportServer::bind_tcp_with_surface(
                    "127.0.0.1:0",
                    Arc::clone(&batcher),
                    admin,
                )
                .map_err(|e| anyhow::anyhow!("replica {r}: bind tcp: {e}"))?
            }
        };
        endpoints.push(transport.endpoint().clone());
        nodes.push(ClusterNode { server, batcher, transport });
    }
    let cluster = Cluster::connect(
        endpoints,
        ClusterOptions {
            // Generous next to the default 1s: a loaded CI scheduler
            // stalling a replica must not fake a failover in the bench.
            request_timeout: Duration::from_secs(5),
            hedge: spec.hedge,
            virtual_nodes: spec.virtual_nodes,
        },
    );
    cluster.seed(&partitions);
    let completed = Arc::new(AtomicU64::new(0));

    struct ChurnOut {
        latencies_ns: Vec<u64>,
        adds: u64,
        retires: u64,
        churn_done: Option<(Instant, u64)>,
    }
    type ReaderOut = (Vec<u64>, [u64; 3]);
    let t0 = Instant::now();
    let (reader_out, churn_out, wall, run_end) =
        std::thread::scope(|scope| {
            // Churn driver: structural mutations through the router, so
            // every add/retire takes the replication-log path the
            // cluster ships with. The driver owns the live-id pool
            // (global ids), exactly like the single-node loop.
            let driver = spec.churn.map(|c| {
                let completed = Arc::clone(&completed);
                let cluster = &cluster;
                let pause = spec.swap_pause;
                let seed = spec.seed ^ 0x57A9_0000_0000_0000;
                scope.spawn(move || {
                    let mut router = cluster.client();
                    let mut rng = Rng::seeded(seed);
                    let mut live: Vec<u32> = (0..n as u32).collect();
                    let floor = (n / 2).max(2);
                    let mut out = ChurnOut {
                        latencies_ns: Vec::new(),
                        adds: 0,
                        retires: 0,
                        churn_done: None,
                    };
                    for _ in 0..c.ops {
                        let retire_ok = live.len() >= floor + c.batch;
                        if !retire_ok && c.adds == 0 {
                            break;
                        }
                        let want_add = c.retires == 0
                            || (c.adds > 0
                                && rng.below((c.adds + c.retires) as u64)
                                    < c.adds as u64);
                        if want_add || !retire_ok {
                            let mut emb = Matrix::zeros(c.batch, dim);
                            for r in 0..c.batch {
                                let v = unit_vector(&mut rng, dim);
                                emb.row_mut(r).copy_from_slice(&v);
                            }
                            let t = Instant::now();
                            let (globals, _seq) = router.add_classes(&emb);
                            out.latencies_ns
                                .push(t.elapsed().as_nanos() as u64);
                            live.extend_from_slice(&globals);
                            out.adds += c.batch as u64;
                        } else {
                            let victims: Vec<u32> = rng
                                .sample_distinct(live.len(), c.batch)
                                .into_iter()
                                .map(|i| live[i])
                                .collect();
                            let t = Instant::now();
                            router.retire_classes(&victims);
                            out.latencies_ns
                                .push(t.elapsed().as_nanos() as u64);
                            live.retain(|id| !victims.contains(id));
                            out.retires += c.batch as u64;
                        }
                        if !pause.is_zero() {
                            std::thread::sleep(pause);
                        }
                    }
                    out.churn_done = Some((
                        Instant::now(),
                        completed.load(Ordering::Relaxed),
                    ));
                    out
                })
            });
            let handles: Vec<_> = (0..spec.readers)
                .map(|r| {
                    let completed = Arc::clone(&completed);
                    let cluster = &cluster;
                    scope.spawn(move || {
                        let mut router = cluster.client();
                        let mut rng = Rng::seeded(
                            spec.seed.wrapping_add(
                                (r as u64).wrapping_mul(0x9E37_79B9),
                            ),
                        );
                        let mut lat = Vec::with_capacity(
                            spec.requests_per_reader / spec.wave + 1,
                        );
                        let mut counts = [0u64; 3];
                        let mut left = spec.requests_per_reader;
                        while left > 0 {
                            let w = spec.wave.min(left);
                            left -= w;
                            let mut kinds = Vec::with_capacity(w);
                            let queries: Vec<ClusterQuery> = (0..w)
                                .map(|_| {
                                    let kind = spec.mix.pick(&mut rng);
                                    kinds.push(kind);
                                    let h = unit_vector(&mut rng, dim);
                                    match kind {
                                        ReqKind::Sample => {
                                            ClusterQuery::Sample {
                                                h,
                                                m: spec.m,
                                                seed: rng.next_u64(),
                                            }
                                        }
                                        ReqKind::Prob => {
                                            ClusterQuery::Probability {
                                                h,
                                                class: rng.index(n) as u32,
                                            }
                                        }
                                        ReqKind::TopK => ClusterQuery::TopK {
                                            h,
                                            k: spec.top_k,
                                        },
                                    }
                                })
                                .collect();
                            let t = Instant::now();
                            let results =
                                router.query_burst(&queries, spec.wave > 1);
                            lat.push(t.elapsed().as_nanos() as u64);
                            completed.fetch_add(w as u64, Ordering::Relaxed);
                            for (kind, res) in kinds.iter().zip(results) {
                                match res {
                                    Ok(reply) => {
                                        std::hint::black_box(&reply);
                                    }
                                    // A probability for a class the
                                    // churn driver retired is a correct
                                    // cluster answer, not a failure.
                                    Err(ClusterError::UnknownClass(_))
                                        if *kind == ReqKind::Prob => {}
                                    Err(e) => panic!(
                                        "cluster request failed: {e}"
                                    ),
                                }
                                counts[match kind {
                                    ReqKind::Sample => 0,
                                    ReqKind::Prob => 1,
                                    ReqKind::TopK => 2,
                                }] += 1;
                            }
                        }
                        (lat, counts)
                    })
                })
                .collect();
            let reader_out: Vec<ReaderOut> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let wall = t0.elapsed().as_secs_f64();
            let run_end = Instant::now();
            let churn_out = driver
                .map(|h| h.join().expect("cluster churn driver panicked"));
            (reader_out, churn_out, wall, run_end)
        });

    // Steady-state replication lag, sampled before the converging
    // flush; then await convergence so live_final and the cursors
    // reflect every mutation the run appended.
    let repl_lag = cluster.lag().into_iter().max().unwrap_or(0);
    anyhow::ensure!(
        cluster.flush(Duration::from_secs(30)),
        "cluster load: replication did not converge within 30s"
    );
    let repl_dropped: u64 = cluster.dropped().iter().sum();
    let mx = cluster.metrics();
    let hedges_fired = mx.counter("cluster.hedges_fired").get();
    let hedges_won = mx.counter("cluster.hedges_won").get();
    let failovers = mx.counter("cluster.failovers").get();

    let mut all: Vec<u64> = Vec::new();
    let mut kind_counts = [0u64; 3];
    for (lat, counts) in reader_out {
        all.extend(lat);
        for (acc, c) in kind_counts.iter_mut().zip(counts) {
            *acc += c;
        }
    }
    all.sort_unstable();
    let pct = |q: f64| -> f64 {
        if all.is_empty() {
            return 0.0;
        }
        all[((all.len() - 1) as f64 * q).round() as usize] as f64 / 1000.0
    };
    let requests = kind_counts.iter().sum::<u64>();
    // Logical requests count once however many hedges/retries served
    // them — the router's core accounting invariant.
    debug_assert_eq!(mx.counter("cluster.requests").get(), requests);
    let mean_us = if all.is_empty() {
        0.0
    } else {
        all.iter().sum::<u64>() as f64 / all.len() as f64 / 1000.0
    };
    // Server-side accounting summed over replicas (read before the
    // transports drop), plus replica 0's stage breakdown.
    let mut batches = 0u64;
    let mut served = 0u64;
    let mut swap_stalls = 0u64;
    let mut epochs = 0u64;
    let mut live_final = 0u64;
    let mut req_frames = 0u64;
    let mut wave_frames = 0u64;
    for node in &nodes {
        let b = node.batcher.stats();
        batches += b.batches;
        served += b.requests;
        epochs = epochs.max(node.server.epoch());
        swap_stalls += node.server.swap_stalls();
        live_final +=
            node.server.snapshot().sampler().live_classes() as u64;
        let ws = node.transport.stats();
        req_frames += ws.request_frames;
        wave_frames += ws.wave_frames;
    }
    let stages = nodes[0].batcher.telemetry().stages_json();
    let mean_request_ns = mean_us * 1000.0 / spec.wave as f64;
    let telemetry_overhead_pct = measure_telemetry_overhead(mean_request_ns);
    let (frame_encode_us, frame_encode_fresh_us, frame_decode_us) =
        measure_codec_overhead(spec);
    let (wave_encode_us, wave_decode_us) = measure_wave_overhead(spec);
    let (mutations, adds, retires, mut_p50_us, mut_p99_us, post_churn_qps) =
        match churn_out {
            Some(mut c) if !c.latencies_ns.is_empty() => {
                c.latencies_ns.sort_unstable();
                let mpct = |q: f64| -> f64 {
                    c.latencies_ns[((c.latencies_ns.len() - 1) as f64 * q)
                        .round() as usize] as f64
                        / 1000.0
                };
                let tail_qps = match c.churn_done {
                    Some((at, done_count)) => {
                        let tail_secs = run_end
                            .saturating_duration_since(at)
                            .as_secs_f64();
                        let tail_reqs =
                            requests.saturating_sub(done_count) as f64;
                        if tail_secs > 0.0 {
                            tail_reqs / tail_secs
                        } else {
                            0.0
                        }
                    }
                    None => 0.0,
                };
                (
                    c.latencies_ns.len() as u64,
                    c.adds,
                    c.retires,
                    mpct(0.50),
                    mpct(0.99),
                    tail_qps,
                )
            }
            _ => (0, 0, 0, 0.0, 0.0, 0.0),
        };
    let report = LoadReport {
        sampler: name,
        transport: spec.transport.name().to_string(),
        mix: spec.mix.label(),
        readers: spec.readers,
        requests,
        sample_requests: kind_counts[0],
        prob_requests: kind_counts[1],
        topk_requests: kind_counts[2],
        wall_seconds: wall,
        qps: requests as f64 / wall.max(1e-12),
        mean_us,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        batches,
        mean_batch: served as f64 / batches.max(1) as f64,
        epochs,
        swap_stalls,
        frame_encode_us,
        frame_encode_fresh_us,
        frame_decode_us,
        wave: spec.wave,
        req_frames,
        wave_frames,
        resp_frames: 0,
        req_headers_per_request: if requests > 0 {
            req_frames as f64 / requests as f64
        } else {
            0.0
        },
        resp_headers_per_request: 0.0,
        wave_encode_us,
        wave_decode_us,
        churn: spec.churn.map(|c| c.label()).unwrap_or_default(),
        mutations,
        classes_added: adds,
        classes_retired: retires,
        mut_p50_us,
        mut_p99_us,
        post_churn_qps,
        live_final,
        quantize: spec.quantize.name(),
        simd: simd::tier_name(),
        stages,
        telemetry_overhead_pct,
        replicas: spec.replicas,
        repl_lag,
        repl_dropped,
        hedges_fired,
        hedges_won,
        failovers,
    };
    // Keep the replica endpoints scrapeable through the hold window,
    // then tear down the cluster before the transports (the replication
    // worker's admin connections must close before the servers join
    // their connection threads).
    if !spec.hold.is_zero() {
        std::thread::sleep(spec.hold);
    }
    drop(cluster);
    drop(nodes);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::sampler::ShardedKernelSampler;

    fn test_sampler(d: usize) -> ShardedKernelSampler<RffMap> {
        let mut rng = Rng::seeded(700);
        let classes = Matrix::randn(&mut rng, 64, d).l2_normalized_rows();
        let map = RffMap::new(d, 16, 2.0, &mut Rng::seeded(701));
        ShardedKernelSampler::with_map(&classes, map, 4, "rff-sharded")
    }

    /// Per-replica samplers over the ring partition of one shared class
    /// matrix — the construction contract of `run_cluster_closed_loop`.
    fn cluster_samplers(
        n: usize,
        d: usize,
        replicas: usize,
    ) -> Vec<Box<dyn Sampler>> {
        let mut rng = Rng::seeded(700);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        shard_partition(n, replicas, 64)
            .iter()
            .map(|p| {
                let mut shard = Matrix::zeros(p.len(), d);
                for (i, &g) in p.iter().enumerate() {
                    shard.row_mut(i).copy_from_slice(classes.row(g as usize));
                }
                let map = RffMap::new(d, 16, 2.0, &mut Rng::seeded(701));
                Box::new(ShardedKernelSampler::with_map(
                    &shard,
                    map,
                    2,
                    "rff-sharded",
                )) as Box<dyn Sampler>
            })
            .collect()
    }

    #[test]
    fn closed_loop_smoke_under_writer_churn() {
        let d = 8;
        let sampler = test_sampler(d);
        let report = run_closed_loop(
            &sampler,
            &LoadSpec {
                readers: 2,
                requests_per_reader: 60,
                m: 5,
                top_k: 4,
                dim: d,
                seed: 3,
                batcher: BatcherOptions {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                updates_per_swap: 4,
                swap_pause: Duration::from_micros(50),
                transport: TransportMode::Inproc,
                mix: RequestMix::default(),
                churn: None,
                wave: 1,
                listen: "127.0.0.1:0".into(),
                quantize: QuantizeKind::None,
                hold: Duration::ZERO,
                replicas: 1,
                hedge: false,
                virtual_nodes: 64,
                restore: None,
            },
        )
        .unwrap();
        assert_eq!(report.requests, 120);
        assert_eq!(report.sample_requests, 120, "default mix is all-sample");
        assert!(report.qps > 0.0);
        assert!(report.p50_us <= report.p99_us);
        assert!(report.batches >= 1);
        assert!(report.epochs >= 1, "writer never published");
        // JSON record is well-formed and tagged.
        let j = report.to_json();
        assert_eq!(
            j.at(&["bench"]).and_then(|v| v.as_str().map(String::from)),
            Some("serving_closed_loop".into())
        );
        assert_eq!(
            j.at(&["transport"]).and_then(|v| v.as_str().map(String::from)),
            Some("inproc".into())
        );
        assert_eq!(
            j.at(&["quantize"]).and_then(|v| v.as_str().map(String::from)),
            Some("none".into())
        );
        let simd = j.at(&["simd"]).and_then(|v| v.as_str().map(String::from));
        assert!(
            matches!(simd.as_deref(), Some("avx2" | "neon" | "scalar")),
            "unexpected simd tier tag {simd:?}"
        );
        // Stage counts reconcile with the request total: every served
        // request passes through the middle stages exactly once.
        for stage in ["queue_wait", "coalesce", "gemm_wave", "tree_walk"] {
            assert_eq!(
                j.at(&["stages", stage, "count"]).and_then(Json::as_i64),
                Some(120),
                "stage {stage} count does not reconcile"
            );
        }
        // Inproc has no wire, so the transport stages never record and
        // stay absent from the breakdown entirely.
        assert!(j.at(&["stages", "decode"]).is_none());
        assert!(j.at(&["stages", "encode_reply"]).is_none());
        assert!(report.telemetry_overhead_pct >= 0.0);
        assert!(
            report.telemetry_overhead_pct < 50.0,
            "attributed telemetry overhead implausibly high: {}%",
            report.telemetry_overhead_pct
        );
    }

    #[test]
    fn mixed_uds_closed_loop_crosses_the_wire() {
        let d = 8;
        let sampler = test_sampler(d);
        let report = run_closed_loop(
            &sampler,
            &LoadSpec {
                readers: 2,
                requests_per_reader: 40,
                m: 5,
                top_k: 4,
                dim: d,
                seed: 11,
                batcher: BatcherOptions {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                updates_per_swap: 4,
                swap_pause: Duration::from_micros(50),
                transport: TransportMode::Uds,
                mix: RequestMix { sample: 2, prob: 1, topk: 1 },
                churn: None,
                wave: 1,
                listen: "127.0.0.1:0".into(),
                quantize: QuantizeKind::None,
                hold: Duration::ZERO,
                replicas: 1,
                hedge: false,
                virtual_nodes: 64,
                restore: None,
            },
        )
        .unwrap();
        assert_eq!(report.requests, 80);
        assert_eq!(
            report.sample_requests + report.prob_requests + report.topk_requests,
            80
        );
        assert!(report.sample_requests > 0, "mix produced no samples");
        assert_eq!(report.transport, "uds");
        assert_eq!(report.mix, "2:1:1");
        assert!(report.frame_encode_us > 0.0, "codec overhead not measured");
        assert!(report.frame_decode_us > 0.0);
        // On the wire path the transport stages fill in too: one decode
        // per serve request, one encode per reply.
        let j = report.to_json();
        for stage in ["decode", "gemm_wave", "encode_reply"] {
            assert_eq!(
                j.at(&["stages", stage, "count"]).and_then(Json::as_i64),
                Some(80),
                "stage {stage} count does not reconcile over uds"
            );
        }
    }

    #[test]
    fn request_mix_parses_and_rejects() {
        let m = RequestMix::parse("8:1:1").unwrap();
        assert_eq!((m.sample, m.prob, m.topk), (8, 1, 1));
        assert_eq!(m.label(), "8:1:1");
        assert!(RequestMix::parse("0:0:0").is_err());
        assert!(RequestMix::parse("1:2").is_err());
        assert!(RequestMix::parse("a:b:c").is_err());
        assert!(TransportMode::parse("uds").is_ok());
        assert!(TransportMode::parse("tcp").is_ok());
        assert!(TransportMode::parse("http").is_err());
        assert!(!TransportMode::Inproc.is_wire());
        assert!(TransportMode::Uds.is_wire());
        assert!(TransportMode::Tcp.is_wire());
    }

    #[test]
    fn tcp_closed_loop_crosses_the_wire() {
        let d = 8;
        let sampler = test_sampler(d);
        let report = run_closed_loop(
            &sampler,
            &LoadSpec {
                readers: 2,
                requests_per_reader: 40,
                m: 5,
                top_k: 4,
                dim: d,
                seed: 31,
                batcher: BatcherOptions {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                updates_per_swap: 4,
                swap_pause: Duration::from_micros(50),
                transport: TransportMode::Tcp,
                mix: RequestMix { sample: 2, prob: 1, topk: 1 },
                churn: None,
                wave: 1,
                listen: "127.0.0.1:0".into(),
                quantize: QuantizeKind::None,
                hold: Duration::ZERO,
                replicas: 1,
                hedge: false,
                virtual_nodes: 64,
                restore: None,
            },
        )
        .unwrap();
        assert_eq!(report.requests, 80);
        assert_eq!(report.transport, "tcp");
        assert!(report.frame_encode_us > 0.0, "codec overhead not measured");
        // Single-frame pipelining: exactly one parsed header per request
        // on both directions.
        assert_eq!(report.req_frames, 80);
        assert!((report.req_headers_per_request - 1.0).abs() < 1e-9);
        assert!((report.resp_headers_per_request - 1.0).abs() < 1e-9);
        assert_eq!(report.wave_frames, 0);
        assert_eq!(report.wave_encode_us, 0.0);
    }

    #[test]
    fn wave_batching_amortizes_frame_headers() {
        for transport in [TransportMode::Uds, TransportMode::Tcp] {
            let d = 8;
            let wave = 8usize;
            let sampler = test_sampler(d);
            let report = run_closed_loop(
                &sampler,
                &LoadSpec {
                    readers: 2,
                    requests_per_reader: 64,
                    m: 5,
                    top_k: 4,
                    dim: d,
                    seed: 41,
                    batcher: BatcherOptions {
                        max_batch: 32,
                        max_wait: Duration::from_micros(100),
                    },
                    updates_per_swap: 4,
                    swap_pause: Duration::from_micros(50),
                    transport,
                    mix: RequestMix { sample: 2, prob: 1, topk: 1 },
                    churn: None,
                    wave,
                    listen: "127.0.0.1:0".into(),
                    quantize: QuantizeKind::None,
                    hold: Duration::ZERO,
                    replicas: 1,
                    hedge: false,
                    virtual_nodes: 64,
                    restore: None,
                },
            )
            .unwrap();
            assert_eq!(report.requests, 128, "{transport:?}");
            assert_eq!(report.wave, wave);
            // Deterministic request-direction amortization: each reader
            // sends exactly ceil(64/8) = 8 wave frames.
            assert_eq!(report.req_frames, 16, "{transport:?}");
            assert_eq!(report.wave_frames, 16, "{transport:?}");
            assert!(
                (report.req_headers_per_request - 1.0 / wave as f64).abs()
                    < 1e-9,
                "{transport:?}: hdr/req {}",
                report.req_headers_per_request
            );
            // ≥ 4× under the wave=1 baseline of 1.0 — the ISSUE 5 gate.
            assert!(report.req_headers_per_request <= 0.25);
            // Replies may pack into wave frames too (never more frames
            // than responses).
            assert!(report.resp_frames <= 128 + 16);
            assert!(report.resp_headers_per_request <= 1.0 + 1e-9);
            assert!(report.wave_encode_us > 0.0);
            assert!(report.wave_decode_us > 0.0);
            let j = report.to_json();
            assert!(j.at(&["req_headers_per_request"]).is_some());
            assert!(j.at(&["wave_encode_us"]).is_some());
        }
    }

    #[test]
    fn cluster_closed_loop_over_two_replicas() {
        let d = 8;
        let samplers = cluster_samplers(64, d, 2);
        let report = run_cluster_closed_loop(
            &samplers,
            &LoadSpec {
                readers: 2,
                requests_per_reader: 40,
                m: 5,
                top_k: 4,
                dim: d,
                seed: 91,
                batcher: BatcherOptions {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                updates_per_swap: 0,
                swap_pause: Duration::from_micros(50),
                transport: TransportMode::Uds,
                mix: RequestMix { sample: 2, prob: 1, topk: 1 },
                churn: Some(ChurnSpec {
                    adds: 1,
                    retires: 1,
                    ops: 6,
                    batch: 2,
                }),
                wave: 4,
                listen: "127.0.0.1:0".into(),
                quantize: QuantizeKind::None,
                hold: Duration::ZERO,
                replicas: 2,
                hedge: false,
                virtual_nodes: 64,
                restore: None,
            },
        )
        .unwrap();
        assert_eq!(report.requests, 80);
        assert_eq!(report.replicas, 2);
        assert!(report.qps > 0.0);
        assert!(report.sample_requests > 0);
        assert_eq!(report.mutations, 6);
        // The pre-report flush converged every mutation onto its owner:
        // nothing abandoned, and the final live count reconciles with
        // the net churn across all replicas.
        assert_eq!(report.repl_dropped, 0);
        assert_eq!(
            report.live_final,
            64 + report.classes_added - report.classes_retired
        );
        assert_eq!(report.failovers, 0, "no replica died");
        let j = report.to_json();
        assert_eq!(j.at(&["replicas"]).and_then(Json::as_usize), Some(2));
        assert!(j.at(&["repl_lag"]).is_some());
        assert!(j.at(&["hedges_fired"]).is_some());
        assert_eq!(
            j.at(&["transport"]).and_then(|v| v.as_str().map(String::from)),
            Some("uds".into())
        );
    }

    #[test]
    fn cluster_closed_loop_rejects_bad_shapes() {
        let d = 8;
        let samplers = cluster_samplers(64, d, 2);
        // replicas must match the sampler count…
        let err = run_cluster_closed_loop(
            &samplers,
            &LoadSpec {
                transport: TransportMode::Uds,
                dim: d,
                replicas: 3,
                ..LoadSpec::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("samplers"), "{err}");
        // …and the cluster path is wire-only.
        let err = run_cluster_closed_loop(
            &samplers,
            &LoadSpec {
                transport: TransportMode::Inproc,
                dim: d,
                replicas: 2,
                ..LoadSpec::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("wire"), "{err}");
    }

    #[test]
    fn churn_spec_parses_and_rejects() {
        let c = ChurnSpec::parse("3:1").unwrap();
        assert_eq!((c.adds, c.retires, c.ops), (3, 1, 200));
        let c = ChurnSpec::parse("2:2:50").unwrap();
        assert_eq!((c.adds, c.retires, c.ops), (2, 2, 50));
        assert_eq!(c.label(), "2:2:50");
        assert!(ChurnSpec::parse("0:0").is_err());
        assert!(ChurnSpec::parse("1").is_err());
        assert!(ChurnSpec::parse("a:b").is_err());
    }

    #[test]
    fn closed_loop_with_churn_reports_mutation_stats() {
        for transport in
            [TransportMode::Inproc, TransportMode::Uds, TransportMode::Tcp]
        {
            let d = 8;
            let sampler = test_sampler(d);
            let report = run_closed_loop(
                &sampler,
                &LoadSpec {
                    readers: 2,
                    requests_per_reader: 80,
                    m: 5,
                    top_k: 4,
                    dim: d,
                    seed: 21,
                    batcher: BatcherOptions {
                        max_batch: 8,
                        max_wait: Duration::from_micros(100),
                    },
                    updates_per_swap: 4,
                    swap_pause: Duration::from_micros(50),
                    transport,
                    mix: RequestMix { sample: 2, prob: 1, topk: 1 },
                    churn: Some(ChurnSpec {
                        adds: 2,
                        retires: 1,
                        ops: 10,
                        batch: 4,
                    }),
                    wave: 1,
                    listen: "127.0.0.1:0".into(),
                    quantize: QuantizeKind::None,
                    hold: Duration::ZERO,
                    replicas: 1,
                    hedge: false,
                    virtual_nodes: 64,
                    restore: None,
                },
            )
            .unwrap();
            assert_eq!(report.requests, 160, "{transport:?}");
            assert_eq!(report.mutations, 10, "{transport:?}");
            assert_eq!(
                report.classes_added + report.classes_retired,
                40,
                "{transport:?}"
            );
            assert!(report.mut_p99_us >= report.mut_p50_us);
            assert!(report.mut_p50_us > 0.0, "{transport:?}");
            assert_eq!(report.churn, "2:1:10");
            // 64 initial classes ± net churn.
            assert_eq!(
                report.live_final,
                64 + report.classes_added - report.classes_retired,
                "{transport:?}"
            );
            let j = report.to_json();
            assert!(j.at(&["mut_p99_us"]).is_some());
            assert!(j.at(&["post_churn_qps"]).is_some());
            if transport == TransportMode::Uds {
                assert!(
                    report.frame_encode_fresh_us >= 0.0
                        && report.frame_encode_us > 0.0
                );
            }
        }
    }
}
