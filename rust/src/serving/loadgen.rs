//! Closed-loop load generator for the serving subsystem — the engine
//! behind the `serve-bench` CLI subcommand and `benches/perf_serving.rs`.
//!
//! `R` reader threads each issue `sample` requests back-to-back through
//! the micro-batcher (closed loop: a new request is issued only when the
//! previous reply lands) while an optional writer thread applies batched
//! random class updates to the shadow and publishes — the live-traffic
//! regime of the ROADMAP north star. Reports throughput, latency
//! percentiles, coalescing behaviour, and swap stalls as BENCH JSON.

use super::{BatcherOptions, MicroBatcher, SamplerServer};
use crate::json::Json;
use crate::linalg::{unit_vector, Matrix};
use crate::rng::Rng;
use crate::sampler::Sampler;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Closed-loop run parameters.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    /// Concurrent reader threads.
    pub readers: usize,
    /// Requests issued by each reader.
    pub requests_per_reader: usize,
    /// Negatives per request.
    pub m: usize,
    /// Query / class-embedding dimension d.
    pub dim: usize,
    /// Base seed for query generation and per-request draw seeds.
    pub seed: u64,
    /// Micro-batcher coalescing bounds.
    pub batcher: BatcherOptions,
    /// Classes updated per writer cycle (0 disables the writer).
    pub updates_per_swap: usize,
    /// Pause between writer cycles (approximates a training-step cadence;
    /// 0 = swap as fast as possible).
    pub swap_pause: Duration,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            readers: 4,
            requests_per_reader: 1000,
            m: 20,
            dim: 64,
            seed: 1,
            batcher: BatcherOptions::default(),
            updates_per_swap: 32,
            swap_pause: Duration::from_micros(200),
        }
    }
}

/// What a closed-loop run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub sampler: String,
    pub readers: usize,
    pub requests: u64,
    pub wall_seconds: f64,
    pub qps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub epochs: u64,
    pub swap_stalls: u64,
}

impl LoadReport {
    /// One human-readable summary line.
    pub fn render(&self) -> String {
        format!(
            "{:<14} readers={} qps={:>10.0} p50={:>8.1}µs p99={:>8.1}µs \
             mean_batch={:>5.1} epochs={} swap_stalls={}",
            self.sampler,
            self.readers,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.mean_batch,
            self.epochs,
            self.swap_stalls,
        )
    }

    /// Machine-readable BENCH record (matches the `perf_hotpath` idiom).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::from("serving_closed_loop")),
            ("sampler", Json::from(self.sampler.as_str())),
            ("readers", Json::from(self.readers)),
            ("requests", Json::from(self.requests as usize)),
            ("wall_seconds", Json::from(self.wall_seconds)),
            ("qps", Json::from(self.qps)),
            ("mean_us", Json::from(self.mean_us)),
            ("p50_us", Json::from(self.p50_us)),
            ("p99_us", Json::from(self.p99_us)),
            ("batches", Json::from(self.batches as usize)),
            ("mean_batch", Json::from(self.mean_batch)),
            ("epochs", Json::from(self.epochs as usize)),
            ("swap_stalls", Json::from(self.swap_stalls as usize)),
        ])
    }
}

/// Run one closed-loop load test against a fork of `sampler`. The
/// sampler must support serving forks and its class-embedding dimension
/// must equal `spec.dim` (writer updates are drawn at that width).
pub fn run_closed_loop(
    sampler: &dyn Sampler,
    spec: &LoadSpec,
) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(spec.readers >= 1, "serve load: need ≥ 1 reader");
    anyhow::ensure!(spec.m >= 1, "serve load: need m ≥ 1");
    let serve = sampler.fork().ok_or_else(|| {
        anyhow::anyhow!(
            "sampler '{}' does not support serving forks",
            sampler.name()
        )
    })?;
    let name = serve.name().to_string();
    let num_classes = serve.num_classes();
    let dim = spec.dim;
    let (server, mut writer) = SamplerServer::new(serve);
    let batcher = Arc::new(MicroBatcher::spawn(server.clone(), spec.batcher));
    let stop = Arc::new(AtomicBool::new(false));

    // Writer: apply a batch of random class updates, publish, pause.
    let writer_handle = if spec.updates_per_swap > 0 {
        let stop = Arc::clone(&stop);
        let k = spec.updates_per_swap.min(num_classes);
        let pause = spec.swap_pause;
        let seed = spec.seed ^ 0x57A9_0000_0000_0000;
        Some(std::thread::spawn(move || {
            let mut rng = Rng::seeded(seed);
            while !stop.load(Ordering::Relaxed) {
                let ids: Vec<u32> = rng
                    .sample_distinct(num_classes, k)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                let mut emb = Matrix::zeros(k, dim);
                for r in 0..k {
                    let v = unit_vector(&mut rng, dim);
                    emb.row_mut(r).copy_from_slice(&v);
                }
                writer.apply_updates(ids, emb);
                writer.publish();
                if !pause.is_zero() {
                    std::thread::sleep(pause);
                }
            }
        }))
    } else {
        None
    };

    // Closed-loop readers.
    let t0 = Instant::now();
    let latencies_ns: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..spec.readers)
            .map(|r| {
                let batcher = Arc::clone(&batcher);
                scope.spawn(move || {
                    let mut rng = Rng::seeded(
                        spec.seed
                            .wrapping_add((r as u64).wrapping_mul(0x9E37_79B9)),
                    );
                    let mut lat = Vec::with_capacity(spec.requests_per_reader);
                    for _ in 0..spec.requests_per_reader {
                        let h = unit_vector(&mut rng, dim);
                        let seed = rng.next_u64();
                        let t = Instant::now();
                        let reply = batcher.sample(&h, spec.m, seed);
                        lat.push(t.elapsed().as_nanos() as u64);
                        std::hint::black_box(reply.draw.ids.len());
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = writer_handle {
        // A dead writer means the run served a frozen snapshot — report
        // an error, not a healthy-looking BENCH record.
        anyhow::ensure!(
            h.join().is_ok(),
            "serve load: writer thread panicked (LoadSpec.dim mismatch \
             with the sampler's class-embedding dimension?)"
        );
    }

    let mut all: Vec<u64> = latencies_ns.concat();
    all.sort_unstable();
    let pct = |q: f64| -> f64 {
        if all.is_empty() {
            return 0.0;
        }
        all[((all.len() - 1) as f64 * q).round() as usize] as f64 / 1000.0
    };
    let requests = all.len() as u64;
    let mean_us = if all.is_empty() {
        0.0
    } else {
        all.iter().sum::<u64>() as f64 / all.len() as f64 / 1000.0
    };
    let (req_stat, batches) = batcher.stats();
    debug_assert_eq!(req_stat, requests);
    Ok(LoadReport {
        sampler: name,
        readers: spec.readers,
        requests,
        wall_seconds: wall,
        qps: requests as f64 / wall.max(1e-12),
        mean_us,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        batches,
        mean_batch: requests as f64 / (batches.max(1)) as f64,
        epochs: server.epoch(),
        swap_stalls: server.swap_stalls(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::sampler::ShardedKernelSampler;

    #[test]
    fn closed_loop_smoke_under_writer_churn() {
        let mut rng = Rng::seeded(700);
        let d = 8;
        let classes = Matrix::randn(&mut rng, 64, d).l2_normalized_rows();
        let map = RffMap::new(d, 16, 2.0, &mut Rng::seeded(701));
        let sampler =
            ShardedKernelSampler::with_map(&classes, map, 4, "rff-sharded");
        let report = run_closed_loop(
            &sampler,
            &LoadSpec {
                readers: 2,
                requests_per_reader: 60,
                m: 5,
                dim: d,
                seed: 3,
                batcher: BatcherOptions {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                updates_per_swap: 4,
                swap_pause: Duration::from_micros(50),
            },
        )
        .unwrap();
        assert_eq!(report.requests, 120);
        assert!(report.qps > 0.0);
        assert!(report.p50_us <= report.p99_us);
        assert!(report.batches >= 1);
        assert!(report.epochs >= 1, "writer never published");
        // JSON record is well-formed and tagged.
        let j = report.to_json();
        assert_eq!(
            j.at(&["bench"]).and_then(|v| v.as_str().map(String::from)),
            Some("serving_closed_loop".into())
        );
    }
}
