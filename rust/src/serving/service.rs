//! Trainer-side double buffering: the async front end the coordinator's
//! `SamplerService` routes through when `serving.double_buffer` is on.
//!
//! The ROADMAP open item this ships: `update_classes` for step *t* is
//! **staged** — handed to a dedicated writer thread that applies it to
//! the server's shadow sampler while the caller proceeds into step *t*'s
//! loss execution — and the snapshot swap lands at the next step
//! boundary, before step *t+1*'s draw ([`DoubleBufferedSampler::sync`]).
//! Because the swap is forced before every draw that follows staged
//! updates, the served distribution is *exactly* the one a synchronous
//! service would have used: no stale-epoch reads, identical draw streams
//! for fork-exact samplers.

use super::{SamplerServer, SamplerSnapshot, SamplerWriter};
use crate::admin::{AdminError, AdminOp, AdminResponse, AdminSurface};
use crate::linalg::Matrix;
use crate::sampler::{Sampler, ServeSampler, VocabError};
use std::sync::{mpsc, Arc};
use std::time::Instant;

enum WriterMsg {
    Stage { ids: Vec<u32>, embeddings: Matrix },
    /// Structural grow: applied to the shadow, acked with the assigned
    /// ids (the caller usually needs them to size its own tables before
    /// the next step).
    Extend {
        embeddings: Matrix,
        ack: mpsc::SyncSender<Result<Vec<u32>, VocabError>>,
    },
    /// Structural shrink: applied to the shadow, acked so validation
    /// errors surface to the caller instead of killing the writer.
    Retire {
        ids: Vec<u32>,
        ack: mpsc::SyncSender<Result<(), VocabError>>,
    },
    /// Full state replacement from a durable snapshot: staged on the
    /// shadow like churn, visible at the next sync as one epoch swap.
    Restore {
        state: Arc<crate::snapshot::SamplerState>,
        ack: mpsc::SyncSender<Result<(), crate::snapshot::SnapshotError>>,
    },
    Publish { ack: mpsc::SyncSender<u64> },
}

/// Counters surfaced into trainer metrics and bench output.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingStats {
    /// Epoch currently pinned by the consumer.
    pub epoch: u64,
    /// Snapshot publications so far.
    pub publishes: u64,
    /// Publications that could not recycle the retired snapshot
    /// (a reader pinned it past the spin budget).
    pub swap_stalls: u64,
    /// Time the consumer spent blocked in [`DoubleBufferedSampler::sync`]
    /// waiting for staged updates to finish — the part of the tree
    /// refresh that did NOT overlap with the step.
    pub publish_wait_ns: u64,
}

/// Owns the reader handle, a pinned snapshot, and the channel to the
/// writer thread. Single-consumer by design (the trainer loop).
pub struct DoubleBufferedSampler {
    server: SamplerServer,
    /// `None` only during shutdown.
    tx: Option<mpsc::Sender<WriterMsg>>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// The consumer's pinned snapshot. `None` only inside
    /// [`DoubleBufferedSampler::sync`], which releases the pin *before*
    /// requesting the publish — holding it across the swap would keep the
    /// retired snapshot alive and force the writer's O(nD) fork fallback
    /// on every single publish instead of the O(k·D log n) recycle.
    pinned: Option<Arc<SamplerSnapshot>>,
    /// Updates staged since the last publish.
    dirty: bool,
    publish_wait_ns: u64,
}

impl DoubleBufferedSampler {
    /// Fork `sampler` into a served double buffer. Returns `None` when
    /// the sampler does not support serving forks.
    pub fn new(sampler: &dyn Sampler) -> Option<Self> {
        Some(Self::from_serve(sampler.fork()?))
    }

    /// Build from an already-forked servable sampler.
    pub fn from_serve(sampler: Box<dyn ServeSampler>) -> Self {
        let (server, writer) = SamplerServer::new(sampler);
        let (tx, rx) = mpsc::channel::<WriterMsg>();
        let worker = std::thread::Builder::new()
            .name("rfsm-serve-writer".into())
            .spawn(move || writer_loop(writer, &rx))
            .expect("spawn serving writer");
        let pinned = Some(server.snapshot());
        Self {
            server,
            tx: Some(tx),
            worker: Some(worker),
            pinned,
            dirty: false,
            publish_wait_ns: 0,
        }
    }

    fn pinned(&self) -> &Arc<SamplerSnapshot> {
        self.pinned.as_ref().expect("pin released outside sync")
    }

    fn sender(&self) -> &mpsc::Sender<WriterMsg> {
        self.tx.as_ref().expect("serving writer already shut down")
    }

    /// Stage one step's class updates into the shadow copy and return
    /// immediately — the `O(k · D log n)` tree refresh overlaps whatever
    /// the caller does next (the step's loss execution).
    pub fn stage_updates(&mut self, ids: Vec<u32>, embeddings: Matrix) {
        self.sender()
            .send(WriterMsg::Stage { ids, embeddings })
            .expect("serving writer died");
        self.dirty = true;
    }

    /// Deprecated shim over [`AdminSurface::admin_add`], kept for one
    /// release so embedders migrate at leisure.
    #[deprecated(note = "use AdminSurface::admin_add (typed ops/errors)")]
    pub fn extend_vocab(
        &mut self,
        embeddings: Matrix,
    ) -> Result<Vec<u32>, String> {
        self.admin_add(embeddings)
            .map(|(ids, _epoch)| ids)
            .map_err(|e| e.to_string())
    }

    /// Deprecated shim over [`AdminSurface::admin_retire`], kept for one
    /// release so embedders migrate at leisure.
    #[deprecated(note = "use AdminSurface::admin_retire (typed ops/errors)")]
    pub fn retire_classes(&mut self, ids: Vec<u32>) -> Result<(), String> {
        self.admin_retire(ids).map(|_epoch| ()).map_err(|e| e.to_string())
    }

    /// Capture the pinned sampler's full durable state tagged with the
    /// pinned epoch ([`crate::snapshot::Snapshot`]). Staged-but-unsynced
    /// churn is *not* included — call [`DoubleBufferedSampler::sync`]
    /// first if you need it. `None` when the sampler kind has no
    /// snapshot support.
    pub fn snapshot(&self) -> Option<crate::snapshot::Snapshot> {
        let pinned = self.pinned();
        let state = pinned.sampler().snapshot_state()?;
        Some(crate::snapshot::Snapshot { epoch: pinned.epoch(), state })
    }

    /// Stage a full state restore from a durable snapshot; like churn it
    /// becomes visible at the next [`DoubleBufferedSampler::sync`] as one
    /// epoch swap, so draws never observe partial state. On `Err` the
    /// served state is unchanged.
    pub fn restore(
        &mut self,
        state: Arc<crate::snapshot::SamplerState>,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        let (ack_tx, ack_rx) = mpsc::sync_channel(1);
        self.sender()
            .send(WriterMsg::Restore { state, ack: ack_tx })
            .expect("serving writer died");
        ack_rx.recv().expect("serving writer died")?;
        self.dirty = true;
        Ok(())
    }

    /// Step boundary: if updates were staged since the last publish, wait
    /// for the writer to finish applying them, swap the snapshot in, and
    /// re-pin — so the next draw can never read a stale epoch. Returns
    /// the pinned epoch.
    pub fn sync(&mut self) -> u64 {
        if self.dirty {
            let t0 = Instant::now();
            // Release our pin first: the publish retires the snapshot we
            // are holding, and an outstanding `Arc` would force the
            // writer's fork fallback instead of the cheap recycle. We
            // block until the new snapshot is pinned, so no draw can run
            // in the unpinned window.
            self.pinned = None;
            let (ack_tx, ack_rx) = mpsc::sync_channel(1);
            self.sender()
                .send(WriterMsg::Publish { ack: ack_tx })
                .expect("serving writer died");
            let epoch = ack_rx.recv().expect("serving writer died");
            self.publish_wait_ns += t0.elapsed().as_nanos() as u64;
            let snap = self.server.snapshot();
            debug_assert_eq!(snap.epoch(), epoch, "stale-epoch pin");
            self.pinned = Some(snap);
            self.dirty = false;
        }
        self.pinned().epoch()
    }

    /// The pinned snapshot's sampler — what draws should run against.
    /// Stable between [`DoubleBufferedSampler::sync`] calls.
    pub fn sampler(&self) -> &dyn Sampler {
        self.pinned().sampler()
    }

    /// Reader handle (cloneable; for sharing with external serving
    /// front ends like the micro-batcher).
    pub fn server(&self) -> &SamplerServer {
        &self.server
    }

    pub fn stats(&self) -> ServingStats {
        ServingStats {
            epoch: self.pinned().epoch(),
            publishes: self.server.publishes(),
            swap_stalls: self.server.swap_stalls(),
            publish_wait_ns: self.publish_wait_ns,
        }
    }
}

/// The staged-surface impl of the unified admin API: universe churn and
/// restores are applied to the serving shadow and become visible at the
/// next [`DoubleBufferedSampler::sync`] as one epoch swap; the `epoch`
/// in responses is therefore the *currently pinned* epoch (the op lands
/// one sync later). [`AdminOp::Snapshot`] captures the pinned snapshot
/// — sync first if staged churn must be included.
impl AdminSurface for DoubleBufferedSampler {
    fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError> {
        match op {
            AdminOp::AddClasses { embeddings } => {
                // Blocking briefly for the assigned ids — vocabulary
                // growth is rare and callers need the ids to size their
                // own tables before the next step.
                let (ack_tx, ack_rx) = mpsc::sync_channel(1);
                self.sender()
                    .send(WriterMsg::Extend { embeddings, ack: ack_tx })
                    .expect("serving writer died");
                let ids = ack_rx.recv().expect("serving writer died")?;
                self.dirty = true;
                Ok(AdminResponse::Added { ids, epoch: self.pinned().epoch() })
            }
            AdminOp::RetireClasses { ids } => {
                let (ack_tx, ack_rx) = mpsc::sync_channel(1);
                self.sender()
                    .send(WriterMsg::Retire { ids, ack: ack_tx })
                    .expect("serving writer died");
                ack_rx.recv().expect("serving writer died")?;
                self.dirty = true;
                Ok(AdminResponse::Retired { epoch: self.pinned().epoch() })
            }
            AdminOp::Snapshot => {
                let snapshot = self.snapshot().ok_or(
                    AdminError::Unsupported("double-buffered sampler kind"),
                )?;
                Ok(AdminResponse::Snapshot { snapshot: Box::new(snapshot) })
            }
            AdminOp::Restore { state } => {
                self.restore(Arc::new(*state))?;
                Ok(AdminResponse::Restored { epoch: self.pinned().epoch() })
            }
        }
    }
}

impl Drop for DoubleBufferedSampler {
    fn drop(&mut self) {
        // Closing the channel ends the writer loop.
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn writer_loop(mut writer: SamplerWriter, rx: &mpsc::Receiver<WriterMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            WriterMsg::Stage { ids, embeddings } => {
                writer.apply_updates(ids, embeddings);
            }
            WriterMsg::Extend { embeddings, ack } => {
                let _ = ack.send(writer.apply_add_classes(embeddings));
            }
            WriterMsg::Retire { ids, ack } => {
                let _ = ack.send(writer.apply_retire_classes(ids));
            }
            WriterMsg::Restore { state, ack } => {
                let _ = ack.send(writer.apply_restore(state));
            }
            WriterMsg::Publish { ack } => {
                let epoch = writer.publish();
                let _ = ack.send(epoch);
                // Shadow catch-up runs after the ack, so it overlaps the
                // publisher's next phase instead of its step boundary.
                writer.reclaim_shadow();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;
    use crate::rng::Rng;
    use crate::sampler::ShardedKernelSampler;

    fn sharded(n: usize, d: usize, seed: u64) -> ShardedKernelSampler<RffMap> {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
        ShardedKernelSampler::with_map(&classes, map, 4, "rff-sharded")
    }

    #[test]
    fn staged_updates_land_before_the_next_draw() {
        let n = 48;
        let d = 6;
        let mut reference = sharded(n, d, 600);
        let mut served =
            DoubleBufferedSampler::new(&reference).expect("forkable");
        let mut rng = Rng::seeded(601);
        let h = unit_vector(&mut rng, d);

        for step in 1..=6u64 {
            let ids: Vec<u32> = vec![(step % 10) as u32, 40 + step as u32 % 8];
            let mut emb = Matrix::zeros(ids.len(), d);
            for r in 0..ids.len() {
                let v = unit_vector(&mut rng, d);
                emb.row_mut(r).copy_from_slice(&v);
            }
            // Reference applies synchronously; served stages async.
            reference.update_classes(&ids, &emb);
            served.stage_updates(ids, emb);
            // Step boundary: the swap must land before the next draw.
            let epoch = served.sync();
            assert_eq!(epoch, step, "one publish per staged step");
            for i in 0..n {
                let a = served.sampler().probability(&h, i);
                let b = reference.probability(&h, i);
                assert!(
                    (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                    "step {step} class {i}: served {a} vs sync {b}"
                );
            }
        }
        let stats = served.stats();
        assert_eq!(stats.publishes, 6);
        assert_eq!(stats.epoch, 6);
    }

    #[test]
    fn extend_and_retire_land_at_the_next_sync() {
        let n = 32;
        let d = 6;
        let reference = sharded(n, d, 620);
        let mut served = DoubleBufferedSampler::new(&reference).unwrap();
        let mut rng = Rng::seeded(621);
        let h = unit_vector(&mut rng, d);

        let mut emb = Matrix::zeros(2, d);
        for r in 0..2 {
            let v = unit_vector(&mut rng, d);
            emb.row_mut(r).copy_from_slice(&v);
        }
        let (ids, epoch0) = served.admin_add(emb).unwrap();
        assert_eq!(ids, vec![n as u32, n as u32 + 1]);
        assert_eq!(epoch0, 0, "staged surface reports the pinned epoch");
        served.admin_retire(vec![5]).unwrap();
        // Not yet visible on the pinned snapshot...
        assert_eq!(served.sampler().num_classes(), n);
        assert!(served.sampler().probability(&h, 5) > 0.0);
        // ...but exactly one sync later it all lands in one epoch.
        assert_eq!(served.sync(), 1);
        assert_eq!(served.sampler().num_classes(), n + 2);
        assert_eq!(served.sampler().live_classes(), n + 1);
        assert_eq!(served.sampler().probability(&h, 5), 0.0);
        let total: f64 = (0..n + 2)
            .map(|i| served.sampler().probability(&h, i))
            .sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
        // Validation errors surface as typed Err, and the writer survives.
        assert!(matches!(
            served.admin_retire(vec![5]),
            Err(AdminError::Vocab(_))
        ), "double retire");
        assert!(served.admin_retire(vec![9999]).is_err(), "out of range");
        served.stage_updates(
            vec![ids[0]],
            Matrix::from_vec(1, d, h.clone()),
        );
        assert_eq!(served.sync(), 2, "writer alive after rejected mutations");
    }

    #[test]
    fn sync_without_staged_updates_is_free() {
        let reference = sharded(16, 4, 610);
        let mut served = DoubleBufferedSampler::new(&reference).unwrap();
        assert_eq!(served.sync(), 0);
        assert_eq!(served.sync(), 0);
        assert_eq!(served.stats().publishes, 0);
    }

    #[test]
    fn snapshot_then_restore_round_trips_through_the_writer() {
        let n = 40;
        let d = 6;
        let reference = sharded(n, d, 630);
        let mut served = DoubleBufferedSampler::new(&reference).unwrap();
        let mut rng = Rng::seeded(631);
        let h = unit_vector(&mut rng, d);

        // Churn, sync, then capture the durable state at epoch 1.
        served.admin_retire(vec![3, 17]).unwrap();
        assert_eq!(served.sync(), 1);
        let snap = served.admin_snapshot().expect("sharded snapshots");
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.state.live_classes(), n - 2);

        // Diverge: more churn lands at epoch 2.
        served.admin_retire(vec![8]).unwrap();
        assert_eq!(served.sync(), 2);
        assert_eq!(served.sampler().probability(&h, 8), 0.0);

        // Restore rewinds to the captured universe at the next sync —
        // one epoch swap, never a partial state.
        served.admin_restore(snap.state.clone()).unwrap();
        assert_eq!(served.sampler().live_classes(), n - 3, "not yet");
        assert_eq!(served.sync(), 3);
        assert_eq!(served.sampler().live_classes(), n - 2);
        assert!(served.sampler().probability(&h, 8) > 0.0, "8 is back");
        assert_eq!(served.sampler().probability(&h, 3), 0.0, "3 stays gone");
        let total: f64 =
            (0..n).map(|i| served.sampler().probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");

        // The writer survives a rejected restore (wrong kind).
        let bogus = crate::snapshot::SamplerState::Uniform(
            crate::snapshot::UniformState { live: vec![0], index: vec![0] },
        );
        assert!(matches!(
            served.admin_restore(bogus),
            Err(AdminError::Snapshot(_))
        ));
        served.admin_retire(vec![9]).unwrap();
        assert_eq!(served.sync(), 4, "writer alive after rejected restore");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_answer() {
        let reference = sharded(16, 4, 640);
        let mut served = DoubleBufferedSampler::new(&reference).unwrap();
        let mut rng = Rng::seeded(641);
        let mut emb = Matrix::zeros(1, 4);
        emb.row_mut(0).copy_from_slice(&unit_vector(&mut rng, 4));
        let ids = served.extend_vocab(emb).unwrap();
        assert_eq!(ids, vec![16]);
        served.retire_classes(vec![2]).unwrap();
        assert!(served.retire_classes(vec![99]).unwrap_err().contains("admin"));
        assert_eq!(served.sync(), 1);
        assert_eq!(served.sampler().live_classes(), 16);
    }
}
