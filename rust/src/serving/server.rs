//! Epoch-versioned snapshot server: many concurrent readers, one writer,
//! O(1) publication.
//!
//! ## Protocol
//!
//! The server keeps **two published slots**, each a `Mutex<Arc<SamplerSnapshot>>`,
//! plus an `AtomicU64` epoch. Between publications both slots hold the
//! current snapshot; a reader loads the epoch (`Acquire`), locks the slot
//! of matching parity just long enough to clone the `Arc`, and then works
//! entirely on its pinned, immutable snapshot. The single writer applies
//! class updates to a privately-owned **shadow** sampler (never visible
//! to readers) and publishes by storing the shadow into the opposite-parity
//! slot and bumping the epoch (`Release`) — the atomic epoch store is the
//! linearization point. Readers therefore:
//!
//! * never wait on update work (the writer holds a slot lock only for an
//!   `Arc` store, never while touching tree state);
//! * always see a complete, normalized distribution (snapshots are
//!   immutable, so a reader pinning a pre-swap snapshot keeps Σq = 1
//!   even while the writer publishes);
//! * observe a monotonically non-decreasing epoch.
//!
//! ## Shadow recycling
//!
//! Double buffering keeps exactly two full sampler states alive (published
//! + shadow). After a publish, the retired snapshot is reclaimed as the
//! next shadow via `Arc::try_unwrap` (a brief yield loop tolerates
//! stragglers still pinning it) and caught up by replaying the update
//! batches staged during the cycle — `O(k · D log n)`, not a full rebuild.
//! The reclamation is **deferred** out of `publish` itself (run lazily
//! before the next update, or eagerly by the serving writer thread after
//! it acks) so a publisher blocking on the step boundary never waits
//! behind the catch-up. If a reader pins the retired snapshot past the
//! spin budget the writer forks the published state instead and counts a
//! **swap stall** (surfaced in `serve-bench` / `perf_serving` output).

use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::sampler::{NegativeDraw, Sampler, ServeSampler, VocabError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One staged shadow mutation, kept (by value — no copies) in the replay
/// log so the retired snapshot can catch up after recycling. Structural
/// ops replay in order with the embedding updates, so a recycled shadow
/// converges to the exact same universe the published snapshot has.
enum StagedOp {
    Update { ids: Vec<u32>, embeddings: Matrix },
    Add { embeddings: Matrix },
    Retire { ids: Vec<u32> },
    /// Full state replacement from a durable snapshot
    /// ([`crate::snapshot`]). Shared via `Arc` so the replay copy costs
    /// a pointer, not a second `O(n·D)` state.
    Restore { state: Arc<crate::snapshot::SamplerState> },
}

/// How many yield rounds the writer spends waiting for stragglers to drop
/// a retired snapshot before falling back to an O(nD) fork.
const RECLAIM_SPINS: usize = 256;

/// One immutable, epoch-tagged sampler state. Readers pin it via `Arc`;
/// the writer never mutates a published snapshot.
pub struct SamplerSnapshot {
    epoch: u64,
    sampler: Box<dyn ServeSampler>,
}

impl SamplerSnapshot {
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The snapshot's sampler (read-only; `Sync` by construction).
    pub fn sampler(&self) -> &dyn Sampler {
        self.sampler.as_sampler()
    }
}

struct Shared {
    /// Two snapshot slots, indexed by epoch parity. Both hold the current
    /// snapshot between publications; locks guard only `Arc` clone/store.
    slots: [Mutex<Arc<SamplerSnapshot>>; 2],
    /// Publication point: readers pick `slots[epoch & 1]`.
    epoch: AtomicU64,
    swap_stalls: AtomicU64,
    publishes: AtomicU64,
}

/// Cloneable reader handle. All methods are `&self` and safe to call from
/// any number of threads concurrently with the writer.
#[derive(Clone)]
pub struct SamplerServer {
    shared: Arc<Shared>,
}

impl SamplerServer {
    /// Wrap a servable sampler; returns the shared reader handle and the
    /// single [`SamplerWriter`]. The writer's shadow starts as a fork of
    /// the initial snapshot, so construction holds two sampler copies —
    /// the inherent cost of double buffering.
    pub fn new(sampler: Box<dyn ServeSampler>) -> (SamplerServer, SamplerWriter) {
        let shadow = sampler
            .fork()
            .expect("SamplerServer: sampler must support fork()");
        let snap = Arc::new(SamplerSnapshot { epoch: 0, sampler });
        let shared = Arc::new(Shared {
            slots: [Mutex::new(Arc::clone(&snap)), Mutex::new(snap)],
            epoch: AtomicU64::new(0),
            swap_stalls: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
        });
        let server = SamplerServer { shared };
        let writer = SamplerWriter {
            server: server.clone(),
            shadow: Some(shadow),
            replay: Vec::new(),
            pending: None,
        };
        (server, writer)
    }

    /// Pin the current snapshot. O(1): one atomic load plus an `Arc`
    /// clone under a momentary slot lock.
    ///
    /// A reader racing a mid-flight publish can pick up a snapshot
    /// *newer* than the epoch it loaded (the writer stores the slot
    /// before bumping the epoch). Without correction, a later call could
    /// then return the older current snapshot — an epoch regression. The
    /// `fetch_max` below "helps" the epoch forward to what was actually
    /// observed, so every subsequent load on any thread sees at least
    /// this snapshot's epoch: per-reader epochs stay monotone, and
    /// readers still never wait on the writer (the help is one lock-free
    /// atomic max).
    pub fn snapshot(&self) -> Arc<SamplerSnapshot> {
        let e = self.shared.epoch.load(Ordering::Acquire);
        let snap =
            Arc::clone(&self.shared.slots[(e & 1) as usize].lock().unwrap());
        if snap.epoch() > e {
            self.shared.epoch.fetch_max(snap.epoch(), Ordering::AcqRel);
        }
        snap
    }

    /// Latest published epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// Times the writer had to fork instead of recycling a retired
    /// snapshot because a reader still pinned it.
    pub fn swap_stalls(&self) -> u64 {
        self.shared.swap_stalls.load(Ordering::Relaxed)
    }

    /// Total publications (== current epoch, kept separate for clarity
    /// in stats plumbing).
    pub fn publishes(&self) -> u64 {
        self.shared.publishes.load(Ordering::Relaxed)
    }

    /// One-shot convenience: draw `m` classes from the current snapshot.
    /// Returns the draw and the epoch it was served from.
    pub fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> (NegativeDraw, u64) {
        let snap = self.snapshot();
        (snap.sampler().sample(h, m, rng), snap.epoch())
    }

    /// One-shot convenience: `q(class | h)` under the current snapshot.
    pub fn probability(&self, h: &[f32], class: usize) -> f64 {
        self.snapshot().sampler().probability(h, class)
    }

    /// One-shot convenience: top-k classes under the current snapshot.
    pub fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        self.snapshot().sampler().top_k(h, k)
    }

    /// Capture the published sampler's full durable state, tagged with
    /// the epoch it was captured at ([`crate::snapshot::Snapshot`]).
    /// Reads the pinned snapshot only — the writer is never involved,
    /// so capture runs concurrently with serving traffic. `None` when
    /// the sampler kind has no snapshot support.
    pub fn snapshot_state(&self) -> Option<crate::snapshot::Snapshot> {
        let snap = self.snapshot();
        let state = snap.sampler().snapshot_state()?;
        Some(crate::snapshot::Snapshot { epoch: snap.epoch(), state })
    }
}

/// The single writer: owns the shadow sampler, applies batched class
/// updates to it off the readers' path, and publishes with an O(1)
/// epoch-tagged swap at step boundaries.
pub struct SamplerWriter {
    server: SamplerServer,
    /// Writer-private state; `None` while a retired snapshot is pending
    /// reclamation (see [`SamplerWriter::reclaim_shadow`]).
    shadow: Option<Box<dyn ServeSampler>>,
    /// Mutations applied to the shadow since the last publish — replayed
    /// onto the recycled snapshot so it catches up in O(k·D log n).
    replay: Vec<StagedOp>,
    /// `(retired, current)` snapshot pair from the last publish, awaiting
    /// reclamation into the next shadow. Deferred so a caller blocking on
    /// `publish`'s return (the trainer's step boundary) never waits
    /// behind a second application of the cycle's updates.
    pending: Option<(Arc<SamplerSnapshot>, Arc<SamplerSnapshot>)>,
}

impl SamplerWriter {
    /// Reader handle for this server (cloneable).
    pub fn server(&self) -> &SamplerServer {
        &self.server
    }

    /// Apply one batch of class updates (`classes[k]` takes
    /// `embeddings.row(k)`; ids unique, embeddings already normalized if
    /// the sampler expects that) to the **shadow** copy, then keep the
    /// owned batch in the replay log (no copies — this is why the
    /// arguments are by value). Readers keep sampling the published
    /// snapshot untouched; the change becomes visible at the next
    /// [`SamplerWriter::publish`].
    pub fn apply_updates(&mut self, classes: Vec<u32>, embeddings: Matrix) {
        self.reclaim_shadow();
        let shadow = self.shadow.as_mut().expect("apply_updates: no shadow");
        shadow.update_classes(&classes, &embeddings);
        self.replay.push(StagedOp::Update { ids: classes, embeddings });
    }

    /// Stage a **structural** mutation: append `embeddings.rows()` new
    /// classes to the shadow's universe, returning their assigned ids.
    /// Readers keep serving the published snapshot — they can never
    /// observe a half-grown tree; the grown universe becomes visible
    /// atomically at the next [`SamplerWriter::publish`] (an
    /// epoch-versioned swap, like every other change). Id assignment is
    /// deterministic in the sampler's slot count, so the replay catch-up
    /// on the recycled snapshot reproduces identical ids.
    pub fn apply_add_classes(
        &mut self,
        embeddings: Matrix,
    ) -> Result<Vec<u32>, VocabError> {
        self.reclaim_shadow();
        let shadow = self.shadow.as_mut().expect("apply_add_classes: no shadow");
        let ids = shadow.add_classes(&embeddings)?;
        self.replay.push(StagedOp::Add { embeddings });
        Ok(ids)
    }

    /// Stage a structural retire of live classes on the shadow; the
    /// holes become visible at the next publish, as one epoch swap.
    pub fn apply_retire_classes(
        &mut self,
        ids: Vec<u32>,
    ) -> Result<(), VocabError> {
        self.reclaim_shadow();
        let shadow =
            self.shadow.as_mut().expect("apply_retire_classes: no shadow");
        shadow.retire_classes(&ids)?;
        self.replay.push(StagedOp::Retire { ids });
        Ok(())
    }

    /// Stage a **full state restore** from a durable snapshot
    /// ([`crate::snapshot`]): the shadow's state is replaced wholesale
    /// (validated + fingerprint-checked by the sampler's
    /// [`crate::sampler::Sampler::restore_state`]), and readers keep
    /// serving the published snapshot untouched until the next
    /// [`SamplerWriter::publish`] swaps the restored universe in as one
    /// epoch step — a restore is a peer of churn in the replay log, so
    /// partial state can never escape. On error the shadow is
    /// unchanged (restore validates before mutating).
    pub fn apply_restore(
        &mut self,
        state: Arc<crate::snapshot::SamplerState>,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        self.reclaim_shadow();
        let shadow = self.shadow.as_mut().expect("apply_restore: no shadow");
        shadow.restore_state(&state)?;
        self.replay.push(StagedOp::Restore { state });
        Ok(())
    }

    /// Publish the shadow as the new snapshot: two momentary `Arc` stores
    /// and one atomic epoch bump, nothing else — the replay catch-up that
    /// rebuilds the next shadow is deferred to
    /// [`SamplerWriter::reclaim_shadow`] (run lazily before the next
    /// update, or eagerly by the serving writer thread right after it
    /// acks), so it overlaps the publisher's next phase instead of
    /// blocking the step boundary. Returns the new epoch.
    pub fn publish(&mut self) -> u64 {
        self.reclaim_shadow();
        let shadow = self.shadow.take().expect("publish: no shadow");
        let shared = &self.server.shared;
        let prev = shared.epoch.load(Ordering::Relaxed);
        let next = prev + 1;
        let snap = Arc::new(SamplerSnapshot { epoch: next, sampler: shadow });

        // Install in the new-parity slot, then flip the epoch — the
        // single atomic publication point.
        *shared.slots[(next & 1) as usize].lock().unwrap() = Arc::clone(&snap);
        shared.epoch.store(next, Ordering::Release);

        // Retire the old snapshot: swap the stale-parity slot to the new
        // snapshot too (stragglers that loaded the old epoch just get the
        // newer state — still consistent), and park the retired Arc for
        // deferred recycling.
        let retired = std::mem::replace(
            &mut *shared.slots[(prev & 1) as usize].lock().unwrap(),
            Arc::clone(&snap),
        );
        shared.publishes.fetch_add(1, Ordering::Relaxed);
        self.pending = Some((retired, snap));
        next
    }

    /// Rebuild the shadow from the last publish's retired snapshot:
    /// `Arc::try_unwrap` recycles its allocation (a brief yield loop
    /// tolerates straggler readers) and this cycle's replay log catches
    /// it up in O(k·D log n); if a reader pins it past the spin budget,
    /// fork the current snapshot instead and count a swap stall. No-op
    /// when nothing is pending.
    pub fn reclaim_shadow(&mut self) {
        let Some((mut retired, current)) = self.pending.take() else {
            return;
        };
        let mut reclaimed: Option<Box<dyn ServeSampler>> = None;
        for _ in 0..RECLAIM_SPINS {
            match Arc::try_unwrap(retired) {
                Ok(s) => {
                    reclaimed = Some(s.sampler);
                    break;
                }
                Err(still_pinned) => {
                    retired = still_pinned;
                    std::thread::yield_now();
                }
            }
        }
        match reclaimed {
            Some(mut sampler) => {
                // One publish behind: replay that cycle's mutations in
                // order (structural ops included — add ids re-assign
                // deterministically from the slot count).
                for op in self.replay.drain(..) {
                    match op {
                        StagedOp::Update { ids, embeddings } => {
                            sampler.update_classes(&ids, &embeddings);
                        }
                        StagedOp::Add { embeddings } => {
                            sampler
                                .add_classes(&embeddings)
                                .expect("replay: add_classes diverged");
                        }
                        StagedOp::Retire { ids } => {
                            sampler
                                .retire_classes(&ids)
                                .expect("replay: retire_classes diverged");
                        }
                        StagedOp::Restore { state } => {
                            sampler
                                .restore_state(&state)
                                .expect("replay: restore_state diverged");
                        }
                    }
                }
                self.shadow = Some(sampler);
            }
            None => {
                // A long-pinned reader owns the retired snapshot; fork the
                // published state (already up to date) instead.
                self.server.shared.swap_stalls.fetch_add(1, Ordering::Relaxed);
                self.replay.clear();
                self.shadow = Some(
                    current
                        .sampler
                        .fork()
                        .expect("reclaim: published sampler must re-fork"),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;
    use crate::sampler::ShardedKernelSampler;

    fn servable(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (Matrix, Box<dyn ServeSampler>) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
        let s = ShardedKernelSampler::with_map(&classes, map, 4, "rff-sharded");
        (classes, Box::new(s))
    }

    fn sum_q(snap: &SamplerSnapshot, h: &[f32], n: usize) -> f64 {
        (0..n).map(|i| snap.sampler().probability(h, i)).sum()
    }

    #[test]
    fn publish_is_visible_and_epoch_tagged() {
        let (_, sampler) = servable(32, 6, 400);
        let (server, mut writer) = SamplerServer::new(sampler);
        assert_eq!(server.epoch(), 0);
        let mut rng = Rng::seeded(401);
        let h = unit_vector(&mut rng, 6);
        let before = server.probability(&h, 3);

        // Stage an update that aligns class 3 with h, then publish.
        let mut emb = Matrix::zeros(1, 6);
        emb.row_mut(0).copy_from_slice(&h);
        writer.apply_updates(vec![3], emb);
        // Not yet visible: readers still see epoch 0.
        assert_eq!(server.epoch(), 0);
        assert_eq!(server.probability(&h, 3), before);

        let e = writer.publish();
        assert_eq!(e, 1);
        assert_eq!(server.epoch(), 1);
        assert!(server.probability(&h, 3) > before);
        assert_eq!(server.snapshot().epoch(), 1);
    }

    #[test]
    fn pinned_pre_swap_snapshot_stays_consistent() {
        let n = 24;
        let (_, sampler) = servable(n, 5, 410);
        let (server, mut writer) = SamplerServer::new(sampler);
        let mut rng = Rng::seeded(411);
        let h = unit_vector(&mut rng, 5);

        let pinned = server.snapshot();
        let q3_before = pinned.sampler().probability(&h, 3);
        let total_before = sum_q(&pinned, &h, n);
        assert!((total_before - 1.0).abs() < 1e-6);

        // Writer churns through several update+publish cycles.
        for step in 0..5u64 {
            let mut emb = Matrix::zeros(2, 5);
            for r in 0..2 {
                let v = unit_vector(&mut rng, 5);
                emb.row_mut(r).copy_from_slice(&v);
            }
            writer.apply_updates(vec![(step % 12) as u32 * 2, 23], emb);
            writer.publish();
        }
        assert_eq!(server.epoch(), 5);

        // The pinned pre-swap snapshot is untouched: same q, Σq = 1.
        assert_eq!(pinned.epoch(), 0);
        assert_eq!(pinned.sampler().probability(&h, 3), q3_before);
        let total_after = sum_q(&pinned, &h, n);
        assert!(
            (total_after - 1.0).abs() < 1e-6,
            "pinned Σq drifted: {total_after}"
        );
        // Holding the pin across publishes forces the fork fallback at
        // least once (the retired snapshot could not be recycled).
        assert!(server.swap_stalls() >= 1);
    }

    #[test]
    fn recycled_shadow_matches_fresh_sampler_exactly() {
        // Drive update+publish cycles WITHOUT long pins, so the shadow is
        // recycled + replayed, and compare against a reference sampler
        // that applied every update synchronously.
        let n = 64;
        let d = 6;
        let (classes, sampler) = servable(n, d, 420);
        let (server, mut writer) = SamplerServer::new(sampler);
        let mut reference = ShardedKernelSampler::with_map(
            &classes,
            RffMap::new(d, 32, 2.0, &mut Rng::seeded(421)),
            4,
            "rff-sharded",
        );
        let mut rng = Rng::seeded(422);
        for step in 0..8 {
            let ids: Vec<u32> =
                (0..6u32).map(|j| (step * 7 + j * 11) % n as u32).collect();
            let mut uniq = ids.clone();
            uniq.sort_unstable();
            uniq.dedup();
            let mut emb = Matrix::zeros(uniq.len(), d);
            for r in 0..uniq.len() {
                let v = unit_vector(&mut rng, d);
                emb.row_mut(r).copy_from_slice(&v);
            }
            reference.update_classes(&uniq, &emb);
            writer.apply_updates(uniq, emb);
            writer.publish();
        }
        assert_eq!(server.swap_stalls(), 0, "no pins → no stalls");
        let h = unit_vector(&mut rng, d);
        let snap = server.snapshot();
        for i in 0..n {
            let a = snap.sampler().probability(&h, i);
            let b = reference.probability(&h, i);
            assert!(
                (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                "class {i}: served {a} vs reference {b}"
            );
        }
    }

    #[test]
    fn structural_mutations_swap_atomically_and_replay_correctly() {
        let n = 24;
        let d = 5;
        let (_, sampler) = servable(n, d, 440);
        let (server, mut writer) = SamplerServer::new(sampler);
        let mut rng = Rng::seeded(441);
        let h = unit_vector(&mut rng, d);

        // Pin the pre-mutation snapshot.
        let pinned_before = server.snapshot();
        assert_eq!(pinned_before.sampler().num_classes(), n);

        // Stage an add + a retire; invisible until publish.
        let mut emb = Matrix::zeros(2, d);
        for r in 0..2 {
            let v = unit_vector(&mut rng, d);
            emb.row_mut(r).copy_from_slice(&v);
        }
        let ids = writer.apply_add_classes(emb).unwrap();
        assert_eq!(ids, vec![n as u32, n as u32 + 1]);
        writer.apply_retire_classes(vec![3]).unwrap();
        assert_eq!(server.snapshot().sampler().num_classes(), n);
        assert!(server.snapshot().sampler().probability(&h, 3) > 0.0);

        // Publish: the grown universe appears in ONE epoch step.
        writer.publish();
        drop(pinned_before);
        let snap = server.snapshot();
        assert_eq!(snap.epoch(), 1);
        assert_eq!(snap.sampler().num_classes(), n + 2);
        assert_eq!(snap.sampler().live_classes(), n + 1);
        assert_eq!(snap.sampler().probability(&h, 3), 0.0);
        assert!(snap.sampler().probability(&h, n) > 0.0);
        let total: f64 = (0..n + 2)
            .map(|i| snap.sampler().probability(&h, i))
            .sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");

        // A second cycle exercises the recycled-shadow structural
        // replay (the retired snapshot must catch up through Add/Retire
        // ops, not just updates).
        let mut emb2 = Matrix::zeros(1, d);
        let v = unit_vector(&mut rng, d);
        emb2.row_mut(0).copy_from_slice(&v);
        drop(snap); // release the pin so the shadow can be recycled
        let ids2 = writer.apply_add_classes(emb2).unwrap();
        assert_eq!(ids2, vec![n as u32 + 2]);
        writer.publish();
        writer.reclaim_shadow();
        let mut emb3 = Matrix::zeros(1, d);
        emb3.row_mut(0).copy_from_slice(&h);
        // Updating the newest class on the recycled shadow only works if
        // the replay grew it to n+3 slots.
        writer.apply_updates(vec![n as u32 + 2], emb3);
        writer.publish();
        let snap = server.snapshot();
        assert_eq!(snap.epoch(), 3);
        assert_eq!(snap.sampler().num_classes(), n + 3);
        assert_eq!(server.swap_stalls(), 0, "no pins → structural recycle");
    }

    #[test]
    fn concurrent_readers_see_monotone_epochs_and_unit_mass() {
        let n = 32;
        let (_, sampler) = servable(n, 5, 430);
        let (server, mut writer) = SamplerServer::new(sampler);
        let stop = Arc::new(AtomicU64::new(0));
        let readers: Vec<_> = (0..4)
            .map(|r| {
                let server = server.clone();
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut rng = Rng::seeded(440 + r);
                    let h = unit_vector(&mut rng, 5);
                    let mut last_epoch = 0u64;
                    while stop.load(Ordering::Relaxed) == 0 {
                        let snap = server.snapshot();
                        assert!(
                            snap.epoch() >= last_epoch,
                            "epoch went backwards: {} < {last_epoch}",
                            snap.epoch()
                        );
                        last_epoch = snap.epoch();
                        let total: f64 = (0..n)
                            .map(|i| snap.sampler().probability(&h, i))
                            .sum();
                        assert!(
                            (total - 1.0).abs() < 1e-6,
                            "Σq = {total} at epoch {}",
                            snap.epoch()
                        );
                    }
                    last_epoch
                })
            })
            .collect();

        let mut rng = Rng::seeded(431);
        for step in 0..40u32 {
            let ids = vec![step % 31, 31];
            let mut emb = Matrix::zeros(2, 5);
            for r in 0..2 {
                let v = unit_vector(&mut rng, 5);
                emb.row_mut(r).copy_from_slice(&v);
            }
            writer.apply_updates(ids, emb);
            writer.publish();
        }
        stop.store(1, Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
        assert_eq!(server.epoch(), 40);
        assert_eq!(server.publishes(), 40);
    }
}
