//! Request micro-batcher: coalesces concurrently-arriving serving
//! queries — `sample`, `probability`, and `top_k` — into one batched
//! serving wave.
//!
//! Client threads submit a query embedding plus a [`ServeQuery`] and
//! either block for the reply (the [`MicroBatcher::sample`]-style
//! wrappers) or hand in a callback ([`MicroBatcher::submit`], the
//! [`crate::transport`] path — one connection can keep many requests in
//! flight). A dedicated batcher thread drains the
//! [`crate::exec::CoalesceQueue`] (bounded by `max_batch` / `max_wait`),
//! pins ONE snapshot for the whole wave, assembles the query matrix, and
//! issues a single [`crate::sampler::Sampler::serve_queries`] — one
//! `map_batch` gemm for the wave *regardless of query kind*, plus
//! per-row tree operations fanned out on the persistent serve pool.
//!
//! **Determinism:** each sample request carries its own seed and
//! `serve_queries` derives an independent RNG stream per row from it
//! (probability/top_k are deterministic given the snapshot). A request's
//! answer therefore depends only on `(query, snapshot epoch)` — never on
//! which other requests it was coalesced with, or on thread scheduling.
//!
//! **Telemetry:** the batcher owns the serving stack's
//! [`LiveRegistry`] ([`MicroBatcher::telemetry`]) and folds every
//! request into the queue-wait / coalesce / gemm-wave / tree-walk
//! stage histograms (batch-shared stages record each request's share,
//! so stage counts reconcile with request totals) plus a worst-N
//! slow-request log. Transport workers clone the registry to add the
//! decode/encode stages; [`MicroBatcher::stats_json`] is the serving
//! portion of the `STATS` wire answer.

use super::SamplerServer;
use crate::exec::CoalesceQueue;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::metrics::live::{LiveRegistry, SlowRequest, Stage, STAGE_COUNT};
use crate::sampler::{NegativeDraw, ServeAnswer, ServeQuery, ServeTrace};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Coalescing bounds (config keys `serving.max_batch` /
/// `serving.max_wait_us`).
#[derive(Clone, Copy, Debug)]
pub struct BatcherOptions {
    /// Maximum requests coalesced into one serving batch.
    pub max_batch: usize,
    /// Maximum *extra* time the batcher waits for the batch to fill
    /// beyond the first queued request. `Duration::ZERO` (the default)
    /// serves whatever has queued as soon as the batcher is free —
    /// "natural batching": under load, requests accumulate while the
    /// previous batch is being served, so coalescing still happens, but
    /// a lightly-loaded closed loop is never taxed a full `max_wait` per
    /// batch (with R blocked closed-loop readers nothing else can
    /// arrive, and waiting would just add latency).
    pub max_wait: Duration,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::ZERO }
    }
}

/// One served sample reply: the draw plus the epoch it was served from.
#[derive(Clone, Debug)]
pub struct ServeReply {
    pub draw: NegativeDraw,
    pub epoch: u64,
}

/// One served answer of any kind, epoch-tagged. Kind-matched to the
/// submitted [`ServeQuery`].
#[derive(Clone, Debug)]
pub enum QueryReply {
    Sample(ServeReply),
    Probability { q: f64, epoch: u64 },
    TopK { items: Vec<(u32, f64)>, epoch: u64 },
}

impl QueryReply {
    /// The snapshot epoch this answer was served from.
    pub fn epoch(&self) -> u64 {
        match self {
            QueryReply::Sample(r) => r.epoch,
            QueryReply::Probability { epoch, .. } => *epoch,
            QueryReply::TopK { epoch, .. } => *epoch,
        }
    }
}

/// Callback invoked with the request's outcome. `Err` carries the serve
/// failure message (e.g. a query dimension the feature map rejects) —
/// the batcher itself survives every failure. Public alias so the
/// transport layer can pre-box callbacks for [`MicroBatcher::submit_wave`].
pub type SubmitReply = Box<dyn FnOnce(Result<QueryReply, String>) + Send>;

type ReplyFn = SubmitReply;

struct Pending {
    h: Vec<f32>,
    query: ServeQuery,
    reply: ReplyFn,
    /// Submit timestamp — queue-wait and total-latency tracing anchor.
    enqueued_at: Instant,
    /// Submit → drain nanoseconds, filled in at drain time.
    queued_ns: u64,
}

#[derive(Default)]
struct BatcherCounters {
    requests: AtomicU64,
    batches: AtomicU64,
    samples: AtomicU64,
    probabilities: AtomicU64,
    top_ks: AtomicU64,
}

/// Point-in-time copy of the micro-batcher's cumulative counters
/// ([`MicroBatcher::stats`]). Named fields — call sites should never
/// have to positionally destructure a stats tuple.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatcherStats {
    /// Requests answered (all kinds, successes only).
    pub requests: u64,
    /// Coalesced serving batches formed (gemm waves issued).
    pub batches: u64,
    /// Sample draws answered.
    pub samples: u64,
    /// Exact-probability queries answered.
    pub probabilities: u64,
    /// Top-k rankings answered.
    pub top_ks: u64,
}

/// Handle to a running micro-batcher. Cheap to share behind an `Arc`;
/// dropping the last handle shuts the batcher thread down.
pub struct MicroBatcher {
    queue: Arc<CoalesceQueue<Pending>>,
    counters: Arc<BatcherCounters>,
    telemetry: LiveRegistry,
    server: SamplerServer,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl MicroBatcher {
    pub fn spawn(server: SamplerServer, opts: BatcherOptions) -> Self {
        assert!(opts.max_batch >= 1, "MicroBatcher: max_batch must be ≥ 1");
        let queue = Arc::new(CoalesceQueue::new());
        let counters = Arc::new(BatcherCounters::default());
        let telemetry = LiveRegistry::new();
        let worker = {
            let queue = Arc::clone(&queue);
            let counters = Arc::clone(&counters);
            let telemetry = telemetry.clone();
            let server = server.clone();
            std::thread::Builder::new()
                .name("rfsm-serve-batcher".into())
                .spawn(move || {
                    batcher_loop(&server, &queue, opts, &counters, &telemetry)
                })
                .expect("spawn serving batcher")
        };
        Self { queue, counters, telemetry, server, worker: Some(worker) }
    }

    /// Enqueue one request without blocking; `reply` is invoked exactly
    /// once from the batcher thread with the outcome (unless the batcher
    /// shuts down first, in which case the callback is dropped
    /// unserved). Returns `false` (dropping the request) after shutdown.
    /// This is the pipelining entry the transport layer uses to keep
    /// many requests per connection in flight.
    pub fn submit(
        &self,
        h: Vec<f32>,
        query: ServeQuery,
        reply: impl FnOnce(Result<QueryReply, String>) + Send + 'static,
    ) -> bool {
        self.queue.push(Pending {
            h,
            query,
            reply: Box::new(reply),
            enqueued_at: Instant::now(),
            queued_ns: 0,
        })
    }

    /// Enqueue a whole decoded wire wave as ONE contiguous run in the
    /// coalescing queue (single lock acquisition), so the wave lands in
    /// a single drain and is served as one coalesced batch — one
    /// `map_batch` gemm for the burst (waves larger than
    /// `serving.max_batch` split across consecutive drains). Every
    /// callback is invoked exactly once, like [`MicroBatcher::submit`];
    /// all-or-nothing `false` after shutdown (dropping the callbacks
    /// unserved — the transport answers those itself).
    pub fn submit_wave(
        &self,
        entries: Vec<(Vec<f32>, ServeQuery, SubmitReply)>,
    ) -> bool {
        let enqueued_at = Instant::now();
        self.queue.push_many(
            entries
                .into_iter()
                .map(|(h, query, reply)| Pending {
                    h,
                    query,
                    reply,
                    enqueued_at,
                    queued_ns: 0,
                })
                .collect(),
        )
    }

    /// Submit one request and block for its reply; panics if the serve
    /// fails (e.g. a query dimension the sampler rejects) or the batcher
    /// is gone.
    fn call(&self, h: &[f32], query: ServeQuery) -> QueryReply {
        let (tx, rx) = mpsc::sync_channel(1);
        let accepted = self.submit(h.to_vec(), query, move |res| {
            let _ = tx.send(res);
        });
        assert!(accepted, "MicroBatcher: request after shutdown");
        rx.recv()
            .expect("MicroBatcher: batcher shut down mid-request")
            .unwrap_or_else(|e| panic!("MicroBatcher: request failed: {e}"))
    }

    /// Submit one sample request and block for its reply. Draw `m`
    /// classes i.i.d. from `q(· | h)` under the snapshot the batcher pins
    /// for this request's batch; `seed` fully determines the draw for a
    /// given epoch.
    pub fn sample(&self, h: &[f32], m: usize, seed: u64) -> ServeReply {
        match self.call(h, ServeQuery::Sample { m, seed }) {
            QueryReply::Sample(r) => r,
            _ => unreachable!("sample query answered with non-sample reply"),
        }
    }

    /// Blocking `q(class | h)` under the batcher's pinned snapshot;
    /// returns `(q, epoch)`.
    pub fn probability(&self, h: &[f32], class: usize) -> (f64, u64) {
        match self.call(h, ServeQuery::Probability { class }) {
            QueryReply::Probability { q, epoch } => (q, epoch),
            _ => unreachable!("probability query answered with other kind"),
        }
    }

    /// Blocking top-k under the batcher's pinned snapshot; returns
    /// `(ranked (class, q) pairs, epoch)`.
    pub fn top_k(&self, h: &[f32], k: usize) -> (Vec<(u32, f64)>, u64) {
        match self.call(h, ServeQuery::TopK { k }) {
            QueryReply::TopK { items, epoch } => (items, epoch),
            _ => unreachable!("top_k query answered with other kind"),
        }
    }

    /// Total proposal mass of the *current* snapshot at query `h`, plus
    /// the epoch it was read from. Answered inline from the snapshot —
    /// never queued through the batcher — because it is a cheap root
    /// lookup the cluster router issues before every mass-weighted
    /// replica pick, and batching it would serialize the router's
    /// fan-out behind unrelated serve traffic.
    pub fn mass(&self, h: &[f32]) -> (f64, u64) {
        let snap = self.server.snapshot();
        (snap.sampler().root_mass(h), snap.epoch())
    }

    /// Cumulative counters as a named snapshot.
    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            requests: self.counters.requests.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            samples: self.counters.samples.load(Ordering::Relaxed),
            probabilities: self.counters.probabilities.load(Ordering::Relaxed),
            top_ks: self.counters.top_ks.load(Ordering::Relaxed),
        }
    }

    /// The serving stack's shared telemetry registry: the batcher
    /// thread records queue-wait / coalesce / gemm / tree-walk stages
    /// into it; transport workers clone it to add decode/encode stages
    /// and their own named counters.
    pub fn telemetry(&self) -> &LiveRegistry {
        &self.telemetry
    }

    /// The serving-stack portion of the STATS wire answer: batcher
    /// counters, snapshot-server state, and the full telemetry
    /// registry snapshot. The transport layer merges its own section
    /// into this object before encoding.
    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            (
                "batcher",
                Json::obj(vec![
                    ("requests", Json::from(s.requests as usize)),
                    ("batches", Json::from(s.batches as usize)),
                    ("samples", Json::from(s.samples as usize)),
                    ("probabilities", Json::from(s.probabilities as usize)),
                    ("top_ks", Json::from(s.top_ks as usize)),
                ]),
            ),
            (
                "server",
                Json::obj(vec![
                    ("epoch", Json::from(self.server.epoch() as usize)),
                    ("publishes", Json::from(self.server.publishes() as usize)),
                    ("swap_stalls", Json::from(self.server.swap_stalls() as usize)),
                ]),
            ),
            ("telemetry", self.telemetry.snapshot_json()),
        ])
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn answer_to_reply(answer: ServeAnswer, epoch: u64) -> QueryReply {
    match answer {
        ServeAnswer::Sample(draw) => QueryReply::Sample(ServeReply { draw, epoch }),
        ServeAnswer::Probability(q) => QueryReply::Probability { q, epoch },
        ServeAnswer::TopK(items) => QueryReply::TopK { items, epoch },
    }
}

/// Best-effort human-readable panic payload.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "serve panicked".to_string()
    }
}

fn batcher_loop(
    server: &SamplerServer,
    queue: &CoalesceQueue<Pending>,
    opts: BatcherOptions,
    counters: &BatcherCounters,
    telemetry: &LiveRegistry,
) {
    while let Some(mut drained) = queue.drain_batch(opts.max_batch, opts.max_wait) {
        debug_assert!(!drained.is_empty());
        let drained_at = Instant::now();
        for r in &mut drained {
            r.queued_ns = drained_at.duration_since(r.enqueued_at).as_nanos() as u64;
            telemetry.record_stage_ns(Stage::QueueWait, r.queued_ns);
        }
        // One snapshot pin serves the whole coalesced drain — every reply
        // in it reports the same epoch.
        let snap = server.snapshot();
        // Per-row validation BEFORE grouping: an out-of-range probability
        // class would panic the sampler's assert mid-wave and fail every
        // coalesced stranger in the same dim group, so reject it here,
        // failing exactly its own caller. (Sample draws accept any m;
        // top_k clamps k internally.)
        let num_classes = snap.sampler().num_classes();
        let mut reqs = Vec::with_capacity(drained.len());
        for r in drained {
            match r.query {
                ServeQuery::Probability { class } if class >= num_classes => {
                    (r.reply)(Err(format!(
                        "probability class {class} out of range (n = \
                         {num_classes})"
                    )));
                }
                _ => reqs.push(r),
            }
        }
        // Group by query dimension so a malformed request can only fail
        // its own group (every member shares the offending dim), never a
        // stranger's — and never this thread: the serve runs under
        // catch_unwind, so a panicking group (a dim the feature map
        // rejects) fails exactly its own callers while the batcher keeps
        // serving everyone else.
        //
        // The coalesce stage clock covers everything between serves:
        // validation, dim-grouping, and the query-matrix build. Each
        // request is charged its *share* of its group's coalesce time,
        // so per-stage counts reconcile with request totals.
        let mut stage_clock = Instant::now();
        while !reqs.is_empty() {
            let d = reqs[0].h.len();
            let group: Vec<Pending> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for r in reqs {
                    if r.h.len() == d {
                        g.push(r);
                    } else {
                        rest.push(r);
                    }
                }
                reqs = rest;
                g
            };
            counters.batches.fetch_add(1, Ordering::Relaxed);
            let queries: Vec<ServeQuery> =
                group.iter().map(|r| r.query).collect();
            // The matrix build cannot panic (row lengths match `d` by
            // construction), so it sits outside catch_unwind, inside
            // the coalesce stage.
            let mut h = Matrix::zeros(group.len(), d);
            for (i, r) in group.iter().enumerate() {
                h.row_mut(i).copy_from_slice(&r.h);
            }
            let coalesce_ns = stage_clock.elapsed().as_nanos() as u64;
            let mut trace = ServeTrace::default();
            let served = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    snap.sampler().serve_queries_traced(&h, &queries, &mut trace)
                }),
            );
            stage_clock = Instant::now();
            let bsz = group.len() as u64;
            let coalesce_share = coalesce_ns / bsz;
            let gemm_share = trace.gemm_ns / bsz;
            let walk_share = trace.walk_ns / bsz;
            match served {
                Ok(answers) => {
                    counters
                        .requests
                        .fetch_add(group.len() as u64, Ordering::Relaxed);
                    for q in &queries {
                        match q {
                            ServeQuery::Sample { .. } => &counters.samples,
                            ServeQuery::Probability { .. } => {
                                &counters.probabilities
                            }
                            ServeQuery::TopK { .. } => &counters.top_ks,
                        }
                        .fetch_add(1, Ordering::Relaxed);
                    }
                    let batch = answers.len();
                    for (req, answer) in group.into_iter().zip(answers) {
                        telemetry.record_stage_ns(Stage::Coalesce, coalesce_share);
                        telemetry.record_stage_ns(Stage::GemmWave, gemm_share);
                        telemetry.record_stage_ns(Stage::TreeWalk, walk_share);
                        let kind = match req.query {
                            ServeQuery::Sample { .. } => "sample",
                            ServeQuery::Probability { .. } => "probability",
                            ServeQuery::TopK { .. } => "top_k",
                        };
                        let mut stage_ns = [0u64; STAGE_COUNT];
                        stage_ns[Stage::QueueWait as usize] = req.queued_ns;
                        stage_ns[Stage::Coalesce as usize] = coalesce_share;
                        stage_ns[Stage::GemmWave as usize] = gemm_share;
                        stage_ns[Stage::TreeWalk as usize] = walk_share;
                        telemetry.offer_slow(SlowRequest {
                            total_ns: req.enqueued_at.elapsed().as_nanos() as u64,
                            kind,
                            batch,
                            epoch: snap.epoch(),
                            stage_ns,
                        });
                        // A client that gave up is not an error; the
                        // callback decides what a dropped receiver means.
                        (req.reply)(Ok(answer_to_reply(answer, snap.epoch())));
                    }
                }
                Err(p) => {
                    // Fail exactly the offending group's callers with the
                    // panic message; the batcher lives on.
                    let msg = panic_msg(p.as_ref());
                    for req in group {
                        (req.reply)(Err(msg.clone()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;
    use crate::rng::Rng;
    use crate::sampler::{ServeSampler, ShardedKernelSampler};

    fn test_server(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (SamplerServer, super::super::SamplerWriter) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
        let s: Box<dyn ServeSampler> = Box::new(ShardedKernelSampler::with_map(
            &classes,
            map,
            4,
            "rff-sharded",
        ));
        SamplerServer::new(s)
    }

    #[test]
    fn single_request_round_trips() {
        let (server, _writer) = test_server(32, 6, 500);
        let batcher = MicroBatcher::spawn(server.clone(), BatcherOptions::default());
        let mut rng = Rng::seeded(501);
        let h = unit_vector(&mut rng, 6);
        let reply = batcher.sample(&h, 10, 7);
        assert_eq!(reply.draw.len(), 10);
        assert_eq!(reply.epoch, 0);
        assert!(reply.draw.ids.iter().all(|&i| (i as usize) < 32));
        // Probabilities are the exact unconditioned snapshot q.
        for (&id, &q) in reply.draw.ids.iter().zip(&reply.draw.probs) {
            let want = server.probability(&h, id as usize);
            assert!((q - want).abs() < 1e-12 * want.max(1e-12));
        }
    }

    #[test]
    fn mixed_kind_requests_coalesce_and_match_direct_queries() {
        let (server, _writer) = test_server(40, 6, 505);
        let batcher = Arc::new(MicroBatcher::spawn(
            server.clone(),
            BatcherOptions { max_batch: 16, max_wait: Duration::from_millis(2) },
        ));
        let mut rng = Rng::seeded(506);
        let h = unit_vector(&mut rng, 6);
        // Issue all three kinds from racing threads against one snapshot.
        let handles: Vec<_> = (0..3)
            .map(|t| {
                let batcher = Arc::clone(&batcher);
                let server = server.clone();
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..20 {
                        match (t + i) % 3 {
                            0 => {
                                let r = batcher.sample(&h, 6, (t * 100 + i) as u64);
                                assert_eq!(r.draw.len(), 6);
                            }
                            1 => {
                                let (q, _) = batcher.probability(&h, 7);
                                let want = server.probability(&h, 7);
                                assert!((q - want).abs() < 1e-15);
                            }
                            _ => {
                                let (items, _) = batcher.top_k(&h, 5);
                                assert_eq!(items, server.top_k(&h, 5));
                            }
                        }
                    }
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        let s = batcher.stats();
        assert_eq!(s.samples + s.probabilities + s.top_ks, 60);
        assert!(s.samples > 0 && s.probabilities > 0 && s.top_ks > 0);
        assert_eq!(s.requests, 60);
        assert!(s.batches >= 1);
        // Stage telemetry reconciles with the counters: every answered
        // request records exactly one queue-wait / coalesce / gemm /
        // tree-walk share.
        let t = batcher.telemetry();
        for stage in [
            Stage::QueueWait,
            Stage::Coalesce,
            Stage::GemmWave,
            Stage::TreeWalk,
        ] {
            assert_eq!(
                t.stage_snapshot(stage).count(),
                60,
                "stage {} count must equal requests",
                stage.name()
            );
        }
        assert!(!t.slow_requests().is_empty());
        let j = batcher.stats_json();
        assert_eq!(j.at(&["batcher", "requests"]).unwrap().as_i64(), Some(60));
        assert_eq!(
            j.at(&["telemetry", "stages", "gemm_wave", "count"])
                .unwrap()
                .as_i64(),
            Some(60)
        );
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let (server, _writer) = test_server(64, 6, 510);
        let batcher = Arc::new(MicroBatcher::spawn(
            server,
            BatcherOptions { max_batch: 16, max_wait: Duration::from_millis(5) },
        ));
        let threads = 4;
        let per_thread = 50;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let batcher = Arc::clone(&batcher);
                std::thread::spawn(move || {
                    let mut rng = Rng::seeded(511 + t);
                    for i in 0..per_thread {
                        let h = unit_vector(&mut rng, 6);
                        let reply =
                            batcher.sample(&h, 5, (t * 1000 + i) as u64);
                        assert_eq!(reply.draw.len(), 5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = batcher.stats();
        assert_eq!(s.requests, (threads * per_thread) as u64);
        assert!(
            s.batches <= s.requests,
            "batches {} > requests {}",
            s.batches,
            s.requests
        );
        assert!(s.batches >= 1);
    }

    #[test]
    fn malformed_request_fails_only_its_caller() {
        let (server, _writer) = test_server(32, 6, 540);
        let batcher =
            Arc::new(MicroBatcher::spawn(server, BatcherOptions::default()));
        // Wrong query dim (4 ≠ 6): the serve panics inside the batcher's
        // catch_unwind, failing this caller only.
        let bad = {
            let b = Arc::clone(&batcher);
            std::thread::spawn(move || b.sample(&[1.0f32; 4], 3, 1))
        };
        assert!(bad.join().is_err(), "wrong-dim request must fail its caller");
        // An out-of-range probability class fails the same way.
        let bad_class = {
            let b = Arc::clone(&batcher);
            std::thread::spawn(move || b.probability(&[1.0f32; 6], 999))
        };
        assert!(bad_class.join().is_err(), "bad class must fail its caller");
        // The batcher thread survives and keeps serving valid requests.
        let mut rng = Rng::seeded(541);
        let h = unit_vector(&mut rng, 6);
        let reply = batcher.sample(&h, 5, 2);
        assert_eq!(reply.draw.len(), 5);
    }

    #[test]
    fn out_of_range_probability_fails_only_its_request_within_a_wave() {
        // Both requests land in ONE coalesced wave (max_wait holds the
        // drain open); the invalid probability must fail alone while the
        // valid same-dim sample in the same wave is served normally.
        let (server, _writer) = test_server(32, 6, 548);
        let batcher = MicroBatcher::spawn(
            server,
            BatcherOptions { max_batch: 8, max_wait: Duration::from_millis(50) },
        );
        let (tx_bad, rx_bad) = mpsc::sync_channel(1);
        let (tx_good, rx_good) = mpsc::sync_channel(1);
        assert!(batcher.submit(
            vec![0.5f32; 6],
            ServeQuery::Probability { class: 999 },
            move |r| {
                let _ = tx_bad.send(r);
            },
        ));
        assert!(batcher.submit(
            vec![0.5f32; 6],
            ServeQuery::Sample { m: 4, seed: 9 },
            move |r| {
                let _ = tx_good.send(r);
            },
        ));
        let bad = rx_bad.recv().unwrap();
        let good = rx_good.recv().unwrap();
        assert!(bad.is_err(), "out-of-range class must fail its caller");
        match good {
            Ok(QueryReply::Sample(r)) => assert_eq!(r.draw.len(), 4),
            other => {
                panic!("valid same-wave request must be served: {other:?}")
            }
        }
    }

    #[test]
    fn submit_delivers_error_instead_of_dropping_the_callback() {
        // The transport path needs a *typed* failure (an Error response
        // frame), not a dropped channel: submit's callback must be
        // invoked with Err on a failing serve.
        let (server, _writer) = test_server(32, 6, 545);
        let batcher = MicroBatcher::spawn(server, BatcherOptions::default());
        let (tx, rx) = mpsc::sync_channel(1);
        let ok = batcher.submit(
            vec![1.0f32; 4], // wrong dim
            ServeQuery::Sample { m: 3, seed: 1 },
            move |res| {
                let _ = tx.send(res);
            },
        );
        assert!(ok);
        let res = rx.recv().expect("callback must run");
        assert!(res.is_err(), "wrong-dim serve must report Err");
    }

    #[test]
    fn same_seed_same_epoch_same_draw_regardless_of_coalescing() {
        let (server, _writer) = test_server(48, 8, 520);
        let mut rng = Rng::seeded(521);
        let h = unit_vector(&mut rng, 8);

        // Serve the probe alone (max_batch 1 ⇒ never coalesced)...
        let solo = {
            let b = MicroBatcher::spawn(
                server.clone(),
                BatcherOptions { max_batch: 1, max_wait: Duration::ZERO },
            );
            b.sample(&h, 12, 999)
        };
        // ...and amid heavy concurrent traffic with aggressive batching.
        let busy = {
            let b = Arc::new(MicroBatcher::spawn(
                server.clone(),
                BatcherOptions {
                    max_batch: 32,
                    max_wait: Duration::from_millis(2),
                },
            ));
            let noise: Vec<_> = (0..4)
                .map(|t| {
                    let b = Arc::clone(&b);
                    std::thread::spawn(move || {
                        let mut rng = Rng::seeded(530 + t);
                        for i in 0..40 {
                            let h = unit_vector(&mut rng, 8);
                            b.sample(&h, 3, (t * 777 + i) as u64);
                        }
                    })
                })
                .collect();
            let reply = b.sample(&h, 12, 999);
            for n in noise {
                n.join().unwrap();
            }
            reply
        };
        assert_eq!(solo.epoch, busy.epoch);
        assert_eq!(solo.draw, busy.draw, "draw depends on coalescing");
    }
}
