//! Request micro-batcher: coalesces concurrently-arriving `sample`
//! queries into one batched serving call.
//!
//! Client threads submit `(h, m, seed)` and block for their reply; a
//! dedicated batcher thread drains the [`crate::exec::CoalesceQueue`]
//! (bounded by `max_batch` / `max_wait`), pins ONE snapshot for the whole
//! batch, assembles the query matrix, and issues a single
//! [`crate::sampler::Sampler::serve_batch`] — one `map_batch` gemm plus
//! fanned-out tree walks, the PR-1 batch path — so serving throughput
//! inherits its amortization.
//!
//! **Determinism:** each request carries its own seed, and `serve_batch`
//! derives an independent RNG stream per row from it. A request's draw
//! therefore depends only on `(seed, snapshot epoch)` — never on which
//! other requests it was coalesced with, or on thread scheduling.

use super::SamplerServer;
use crate::exec::CoalesceQueue;
use crate::linalg::Matrix;
use crate::sampler::NegativeDraw;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Coalescing bounds (config keys `serving.max_batch` /
/// `serving.max_wait_us`).
#[derive(Clone, Copy, Debug)]
pub struct BatcherOptions {
    /// Maximum requests coalesced into one serving batch.
    pub max_batch: usize,
    /// Maximum *extra* time the batcher waits for the batch to fill
    /// beyond the first queued request. `Duration::ZERO` (the default)
    /// serves whatever has queued as soon as the batcher is free —
    /// "natural batching": under load, requests accumulate while the
    /// previous batch is being served, so coalescing still happens, but
    /// a lightly-loaded closed loop is never taxed a full `max_wait` per
    /// batch (with R blocked closed-loop readers nothing else can
    /// arrive, and waiting would just add latency).
    pub max_wait: Duration,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::ZERO }
    }
}

/// One served sample reply: the draw plus the epoch it was served from.
#[derive(Clone, Debug)]
pub struct ServeReply {
    pub draw: NegativeDraw,
    pub epoch: u64,
}

struct PendingSample {
    h: Vec<f32>,
    m: usize,
    seed: u64,
    resp: mpsc::SyncSender<ServeReply>,
}

#[derive(Default)]
struct BatcherStats {
    requests: AtomicU64,
    batches: AtomicU64,
}

/// Handle to a running micro-batcher. Cheap to share behind an `Arc`;
/// dropping the last handle shuts the batcher thread down.
pub struct MicroBatcher {
    queue: Arc<CoalesceQueue<PendingSample>>,
    stats: Arc<BatcherStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl MicroBatcher {
    pub fn spawn(server: SamplerServer, opts: BatcherOptions) -> Self {
        assert!(opts.max_batch >= 1, "MicroBatcher: max_batch must be ≥ 1");
        let queue = Arc::new(CoalesceQueue::new());
        let stats = Arc::new(BatcherStats::default());
        let worker = {
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("rfsm-serve-batcher".into())
                .spawn(move || batcher_loop(&server, &queue, opts, &stats))
                .expect("spawn serving batcher")
        };
        Self { queue, stats, worker: Some(worker) }
    }

    /// Submit one sample request and block for its reply. Draw `m`
    /// classes i.i.d. from `q(· | h)` under the snapshot the batcher pins
    /// for this request's batch; `seed` fully determines the draw for a
    /// given epoch.
    pub fn sample(&self, h: &[f32], m: usize, seed: u64) -> ServeReply {
        let (tx, rx) = mpsc::sync_channel(1);
        let accepted = self.queue.push(PendingSample {
            h: h.to_vec(),
            m,
            seed,
            resp: tx,
        });
        assert!(accepted, "MicroBatcher: sample after shutdown");
        rx.recv().expect(
            "MicroBatcher: request failed (query dimension rejected by the \
             sampler?) or batcher shut down",
        )
    }

    /// `(requests served, batches formed)` so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.stats.requests.load(Ordering::Relaxed),
            self.stats.batches.load(Ordering::Relaxed),
        )
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_loop(
    server: &SamplerServer,
    queue: &CoalesceQueue<PendingSample>,
    opts: BatcherOptions,
    stats: &BatcherStats,
) {
    while let Some(mut reqs) = queue.drain_batch(opts.max_batch, opts.max_wait) {
        debug_assert!(!reqs.is_empty());
        // One snapshot pin serves the whole coalesced drain — every reply
        // in it reports the same epoch.
        let snap = server.snapshot();
        // Group by query dimension so one malformed request can only fail
        // its own group, never a stranger's — and never this thread: the
        // serve runs under catch_unwind, so a panicking group (e.g. a dim
        // the feature map rejects) drops its reply senders (those callers
        // see the failure) while the batcher keeps serving everyone else.
        while !reqs.is_empty() {
            let d = reqs[0].h.len();
            let group: Vec<PendingSample> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for r in reqs {
                    if r.h.len() == d {
                        g.push(r);
                    } else {
                        rest.push(r);
                    }
                }
                reqs = rest;
                g
            };
            stats.batches.fetch_add(1, Ordering::Relaxed);
            let served = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let mut h = Matrix::zeros(group.len(), d);
                    for (i, r) in group.iter().enumerate() {
                        h.row_mut(i).copy_from_slice(&r.h);
                    }
                    let ms: Vec<usize> = group.iter().map(|r| r.m).collect();
                    let seeds: Vec<u64> =
                        group.iter().map(|r| r.seed).collect();
                    snap.sampler().serve_batch(&h, &ms, &seeds)
                }),
            );
            match served {
                Ok(draws) => {
                    stats
                        .requests
                        .fetch_add(group.len() as u64, Ordering::Relaxed);
                    for (req, draw) in group.into_iter().zip(draws) {
                        // A client that gave up (dropped its receiver) is
                        // not an error.
                        let _ = req
                            .resp
                            .send(ServeReply { draw, epoch: snap.epoch() });
                    }
                }
                Err(_) => {
                    // Dropping the group's senders fails exactly the
                    // offending callers' recv; the batcher lives on.
                    drop(group);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;
    use crate::rng::Rng;
    use crate::sampler::{ServeSampler, ShardedKernelSampler};

    fn test_server(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (SamplerServer, super::super::SamplerWriter) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
        let s: Box<dyn ServeSampler> = Box::new(ShardedKernelSampler::with_map(
            &classes,
            map,
            4,
            "rff-sharded",
        ));
        SamplerServer::new(s)
    }

    #[test]
    fn single_request_round_trips() {
        let (server, _writer) = test_server(32, 6, 500);
        let batcher = MicroBatcher::spawn(server.clone(), BatcherOptions::default());
        let mut rng = Rng::seeded(501);
        let h = unit_vector(&mut rng, 6);
        let reply = batcher.sample(&h, 10, 7);
        assert_eq!(reply.draw.len(), 10);
        assert_eq!(reply.epoch, 0);
        assert!(reply.draw.ids.iter().all(|&i| (i as usize) < 32));
        // Probabilities are the exact unconditioned snapshot q.
        for (&id, &q) in reply.draw.ids.iter().zip(&reply.draw.probs) {
            let want = server.probability(&h, id as usize);
            assert!((q - want).abs() < 1e-12 * want.max(1e-12));
        }
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let (server, _writer) = test_server(64, 6, 510);
        let batcher = Arc::new(MicroBatcher::spawn(
            server,
            BatcherOptions { max_batch: 16, max_wait: Duration::from_millis(5) },
        ));
        let threads = 4;
        let per_thread = 50;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let batcher = Arc::clone(&batcher);
                std::thread::spawn(move || {
                    let mut rng = Rng::seeded(511 + t);
                    for i in 0..per_thread {
                        let h = unit_vector(&mut rng, 6);
                        let reply =
                            batcher.sample(&h, 5, (t * 1000 + i) as u64);
                        assert_eq!(reply.draw.len(), 5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (reqs, batches) = batcher.stats();
        assert_eq!(reqs, (threads * per_thread) as u64);
        assert!(batches <= reqs, "batches {batches} > requests {reqs}");
        assert!(batches >= 1);
    }

    #[test]
    fn malformed_request_fails_only_its_caller() {
        let (server, _writer) = test_server(32, 6, 540);
        let batcher =
            Arc::new(MicroBatcher::spawn(server, BatcherOptions::default()));
        // Wrong query dim (4 ≠ 6): the serve panics inside the batcher's
        // catch_unwind, failing this caller only.
        let bad = {
            let b = Arc::clone(&batcher);
            std::thread::spawn(move || b.sample(&[1.0f32; 4], 3, 1))
        };
        assert!(bad.join().is_err(), "wrong-dim request must fail its caller");
        // The batcher thread survives and keeps serving valid requests.
        let mut rng = Rng::seeded(541);
        let h = unit_vector(&mut rng, 6);
        let reply = batcher.sample(&h, 5, 2);
        assert_eq!(reply.draw.len(), 5);
    }

    #[test]
    fn same_seed_same_epoch_same_draw_regardless_of_coalescing() {
        let (server, _writer) = test_server(48, 8, 520);
        let mut rng = Rng::seeded(521);
        let h = unit_vector(&mut rng, 8);

        // Serve the probe alone (max_batch 1 ⇒ never coalesced)...
        let solo = {
            let b = MicroBatcher::spawn(
                server.clone(),
                BatcherOptions { max_batch: 1, max_wait: Duration::ZERO },
            );
            b.sample(&h, 12, 999)
        };
        // ...and amid heavy concurrent traffic with aggressive batching.
        let busy = {
            let b = Arc::new(MicroBatcher::spawn(
                server.clone(),
                BatcherOptions {
                    max_batch: 32,
                    max_wait: Duration::from_millis(2),
                },
            ));
            let noise: Vec<_> = (0..4)
                .map(|t| {
                    let b = Arc::clone(&b);
                    std::thread::spawn(move || {
                        let mut rng = Rng::seeded(530 + t);
                        for i in 0..40 {
                            let h = unit_vector(&mut rng, 8);
                            b.sample(&h, 3, (t * 777 + i) as u64);
                        }
                    })
                })
                .collect();
            let reply = b.sample(&h, 12, 999);
            for n in noise {
                n.join().unwrap();
            }
            reply
        };
        assert_eq!(solo.epoch, busy.epoch);
        assert_eq!(solo.draw, busy.draw, "draw depends on coalescing");
    }
}
