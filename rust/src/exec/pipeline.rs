//! Bounded prefetch pipeline: a producer thread computes items ahead of the
//! consumer, with backpressure once `depth` items are queued. This is how
//! the coordinator overlaps negative sampling for batch `t+1` with PJRT
//! execution of batch `t` (DESIGN.md §1).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Counters exposed by the prefetcher (consumed by [`crate::metrics`]).
#[derive(Debug, Default)]
pub struct PipelineStats {
    /// Times the producer blocked on a full queue (consumer-bound).
    pub producer_stalls: AtomicU64,
    /// Times the consumer blocked on an empty queue (producer-bound).
    pub consumer_stalls: AtomicU64,
    /// Items produced.
    pub produced: AtomicU64,
}

struct Chan<T> {
    queue: Mutex<ChanState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct ChanState<T> {
    items: VecDeque<T>,
    finished: bool,
    cancelled: bool,
}

/// A single-producer single-consumer bounded prefetcher.
pub struct Prefetcher<T: Send + 'static> {
    chan: Arc<Chan<T>>,
    stats: Arc<PipelineStats>,
    producer: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a producer running `make(i)` for `i = 0..count` (or endlessly
    /// when `count` is `None`), keeping at most `depth` items buffered.
    pub fn spawn<F>(depth: usize, count: Option<usize>, make: F) -> Self
    where
        F: FnMut(usize) -> T + Send + 'static,
    {
        assert!(depth > 0, "Prefetcher: depth must be > 0");
        let chan = Arc::new(Chan {
            queue: Mutex::new(ChanState {
                items: VecDeque::with_capacity(depth),
                finished: false,
                cancelled: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        });
        let stats = Arc::new(PipelineStats::default());
        let producer = {
            let chan = Arc::clone(&chan);
            let stats = Arc::clone(&stats);
            let mut make = make;
            std::thread::Builder::new()
                .name("rfsm-prefetch".to_string())
                .spawn(move || {
                    let mut i = 0usize;
                    loop {
                        if let Some(c) = count {
                            if i >= c {
                                break;
                            }
                        }
                        let item = make(i);
                        i += 1;
                        let mut st = chan.queue.lock().unwrap();
                        if st.items.len() >= depth {
                            stats.producer_stalls.fetch_add(1, Ordering::Relaxed);
                            while st.items.len() >= depth && !st.cancelled {
                                st = chan.not_full.wait(st).unwrap();
                            }
                        }
                        if st.cancelled {
                            return;
                        }
                        st.items.push_back(item);
                        stats.produced.fetch_add(1, Ordering::Relaxed);
                        drop(st);
                        chan.not_empty.notify_one();
                    }
                    let mut st = chan.queue.lock().unwrap();
                    st.finished = true;
                    drop(st);
                    chan.not_empty.notify_all();
                })
                .expect("spawn prefetch producer")
        };
        Self { chan, stats, producer: Some(producer) }
    }

    /// Blocking pop; `None` once the producer is done and the queue empty.
    pub fn next(&self) -> Option<T> {
        let mut st = self.chan.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.chan.not_full.notify_one();
                return Some(item);
            }
            if st.finished {
                return None;
            }
            self.stats.consumer_stalls.fetch_add(1, Ordering::Relaxed);
            st = self.chan.not_empty.wait(st).unwrap();
        }
    }

    pub fn stats(&self) -> &PipelineStats {
        &self.stats
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        {
            let mut st = self.chan.queue.lock().unwrap();
            st.cancelled = true;
            st.items.clear();
        }
        self.chan.not_full.notify_all();
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yields_all_items_in_order() {
        let p = Prefetcher::spawn(2, Some(50), |i| i * 3);
        let got: Vec<usize> = std::iter::from_fn(|| p.next()).collect();
        let want: Vec<usize> = (0..50).map(|i| i * 3).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn bounded_depth_causes_producer_stalls() {
        let p = Prefetcher::spawn(1, Some(20), |i| i);
        // Let the producer hit the bound before we consume.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut n = 0;
        while p.next().is_some() {
            n += 1;
        }
        assert_eq!(n, 20);
        assert!(p.stats().producer_stalls.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn drop_cancels_endless_producer() {
        let p: Prefetcher<usize> = Prefetcher::spawn(2, None, |i| i);
        assert_eq!(p.next(), Some(0));
        drop(p); // must not hang
    }

    #[test]
    fn slow_producer_stalls_consumer() {
        let p = Prefetcher::spawn(4, Some(3), |i| {
            std::thread::sleep(std::time::Duration::from_millis(15));
            i
        });
        let got: Vec<usize> = std::iter::from_fn(|| p.next()).collect();
        assert_eq!(got, vec![0, 1, 2]);
        assert!(p.stats().consumer_stalls.load(Ordering::Relaxed) > 0);
    }
}
