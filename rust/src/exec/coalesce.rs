//! Request-coalescing queue — the exec-substrate front end of the
//! serving micro-batcher (`rust/src/serving/batcher.rs`).
//!
//! Many producer threads `push` items; one consumer repeatedly calls
//! [`CoalesceQueue::drain_batch`], which blocks until at least one item
//! is available and then keeps collecting until either `max_batch` items
//! are in hand or `max_wait` has elapsed since the drain started — the
//! standard latency/throughput coalescing trade-off, bounded on both
//! axes. Built on `Mutex` + `Condvar` (std-only, like the rest of
//! [`crate::exec`]).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded-latency batching queue (multi-producer, single-consumer).
pub struct CoalesceQueue<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

impl<T> Default for CoalesceQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CoalesceQueue<T> {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one item. Returns `false` (dropping the item) if the
    /// queue has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        drop(st);
        self.cv.notify_one();
        true
    }

    /// Enqueue a whole batch under ONE lock acquisition, so the items
    /// are contiguous in the queue and a single `drain_batch` collects
    /// them together (up to its `max_batch`) — the wave-aware submit
    /// path: a decoded wire wave lands as one coalesced batch instead of
    /// interleaving with other producers item by item. All-or-nothing:
    /// returns `false` (dropping every item) if the queue is closed.
    pub fn push_many(&self, items: Vec<T>) -> bool {
        if items.is_empty() {
            return true;
        }
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.items.extend(items);
        drop(st);
        // Single consumer: one wake drains the whole contiguous run.
        self.cv.notify_one();
        true
    }

    /// Block until at least one item arrives (or the queue closes), then
    /// collect until `max_batch` items are in hand or `max_wait` elapses.
    /// Returns `None` only when the queue is closed *and* empty — the
    /// consumer's shutdown signal; items pushed before `close` are still
    /// drained.
    pub fn drain_batch(
        &self,
        max_batch: usize,
        max_wait: Duration,
    ) -> Option<Vec<T>> {
        assert!(max_batch >= 1, "drain_batch: max_batch must be ≥ 1");
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.items.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        let deadline = Instant::now() + max_wait;
        while st.items.len() < max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) =
                self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.items.len().min(max_batch);
        Some(st.items.drain(..take).collect())
    }

    /// Close the queue: future pushes are refused, blocked drains wake.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn drains_everything_up_to_max_batch() {
        let q = CoalesceQueue::new();
        for i in 0..10 {
            assert!(q.push(i));
        }
        let b1 = q.drain_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(b1, vec![0, 1, 2, 3]);
        let b2 = q.drain_batch(100, Duration::from_millis(1)).unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn drain_blocks_until_item_arrives() {
        let q = Arc::new(CoalesceQueue::new());
        let qc = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            qc.push(42u32);
        });
        let batch = q.drain_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(batch, vec![42]);
        producer.join().unwrap();
    }

    #[test]
    fn close_wakes_blocked_drain_and_refuses_pushes() {
        let q = Arc::new(CoalesceQueue::<u32>::new());
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            qc.drain_batch(8, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(consumer.join().unwrap().is_none());
        assert!(!q.push(1));
    }

    #[test]
    fn items_before_close_still_drain() {
        let q = CoalesceQueue::new();
        q.push(7u32);
        q.close();
        assert_eq!(q.drain_batch(8, Duration::from_millis(1)), Some(vec![7]));
        assert_eq!(q.drain_batch(8, Duration::from_millis(1)), None);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let q = Arc::new(CoalesceQueue::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let qc = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    assert!(qc.push(t * 100 + i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 400 {
            got.extend(q.drain_batch(64, Duration::from_millis(1)).unwrap());
        }
        got.sort_unstable();
        assert_eq!(got, (0..400).collect::<Vec<_>>());
    }
}
