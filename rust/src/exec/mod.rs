//! Execution substrate: thread pool, bounded channels, the
//! double-buffered prefetch pipeline the coordinator uses to overlap
//! negative sampling (L3) with PJRT execution (runtime), the
//! [`CoalesceQueue`] front end the serving micro-batcher drains, and the
//! process-wide persistent [`serve_pool`] that the serving fan-out
//! ([`serve_map`]) runs on instead of spawning scoped threads per batch.
//!
//! tokio is unavailable offline (DESIGN.md §2); the coordinator's
//! concurrency needs are CPU-bound fan-out + a bounded producer/consumer
//! pipeline, which std threads model directly and predictably.

mod coalesce;
mod pipeline;
mod pool;

pub use coalesce::CoalesceQueue;
pub use pipeline::{Prefetcher, PipelineStats};
pub use pool::ThreadPool;

/// Worker-count heuristic for CPU-bound fan-out (batched sampling walks,
/// sharded tree updates): the machine's available parallelism, capped —
/// kernel-tree walks are memory-bandwidth-bound well before 16 threads.
pub fn recommended_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// The process-wide persistent worker pool behind the serving fan-out
/// ([`serve_map`]): spawned lazily on first use with
/// [`recommended_workers`] threads and shared by every micro-batcher and
/// transport connection in the process. Keeping the workers alive is
/// what removes per-batch thread spawns from the serve path (ROADMAP
/// item) — a coalesced wave costs one FIFO push per worker, not an OS
/// `clone`.
pub fn serve_pool() -> &'static ThreadPool {
    static POOL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(recommended_workers()))
}

/// Run `f(i)` for `i in 0..n` on the shared [`serve_pool`] using up to
/// `workers` pool jobs — the zero-spawn sibling of [`parallel_map`] for
/// the latency-critical serving path. Results in index order; a panic in
/// `f` re-raises here (pool workers survive). Must not be called from
/// inside a pool job (see [`ThreadPool::run_wave`]).
pub fn serve_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let pool = serve_pool();
    slot_map(n, workers.min(pool.size()), f, Some(pool))
}

/// Run `f(i)` for `i in 0..n` across `workers` threads (scoped; borrows
/// allowed). Results are returned in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    slot_map(n, workers, f, None)
}

/// Shared work-stealing scaffolding behind [`parallel_map`] and
/// [`serve_map`]: `workers` jobs race an atomic index over `0..n`,
/// writing results into per-index slots. `pool` picks where the jobs
/// run — `Some` executes them as a [`ThreadPool::run_wave`] on
/// persistent workers, `None` spawns scoped threads.
fn slot_map<T, F>(
    n: usize,
    workers: usize,
    f: F,
    pool: Option<&ThreadPool>,
) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let next = std::sync::atomic::AtomicUsize::new(0);
        let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        let next = &next;
        let slots = &slots;
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..workers)
            .map(|_| {
                Box::new(move || loop {
                    let i =
                        next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    **slots[i].lock().unwrap() = Some(v);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        match pool {
            Some(pool) => pool.run_wave(jobs),
            None => {
                std::thread::scope(|scope| {
                    for job in jobs {
                        scope.spawn(job);
                    }
                });
            }
        }
    }
    out.into_iter().map(|o| o.expect("slot_map: missing slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_map_ordered_and_complete() {
        let got = parallel_map(100, 4, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_runs_on_multiple_threads() {
        // Not strictly guaranteed, but with 8 workers and a yield inside,
        // at least 2 distinct threads should participate.
        let ids = std::sync::Mutex::new(std::collections::HashSet::new());
        parallel_map(64, 8, |_| {
            std::thread::yield_now();
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() >= 2);
    }

    #[test]
    fn recommended_workers_is_sane() {
        let w = recommended_workers();
        assert!((1..=16).contains(&w));
    }

    #[test]
    fn parallel_map_empty() {
        let got: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn work_is_executed_exactly_once() {
        let counter = AtomicUsize::new(0);
        parallel_map(1000, 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn serve_map_matches_parallel_map_semantics() {
        let got = serve_map(100, 4, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
        let empty: Vec<usize> = serve_map(0, 4, |i| i);
        assert!(empty.is_empty());
        // Single-worker request degrades to the serial path.
        let serial = serve_map(10, 1, |i| i + 1);
        assert_eq!(serial, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn serve_map_runs_on_pool_workers_not_fresh_spawns() {
        // Two back-to-back waves must observe the same persistent worker
        // thread ids (the pool is shared and lazily spawned once).
        let collect_ids = || {
            let ids = std::sync::Mutex::new(std::collections::HashSet::new());
            serve_map(64, 8, |_| {
                std::thread::yield_now();
                ids.lock()
                    .unwrap()
                    .insert(std::thread::current().id());
            });
            ids.into_inner().unwrap()
        };
        let a = collect_ids();
        let b = collect_ids();
        assert!(!a.is_empty());
        assert!(
            a.intersection(&b).count() >= 1,
            "waves shared no pool worker: {a:?} vs {b:?}"
        );
    }
}
