//! Execution substrate: thread pool, bounded channels, the
//! double-buffered prefetch pipeline the coordinator uses to overlap
//! negative sampling (L3) with PJRT execution (runtime), and the
//! [`CoalesceQueue`] front end the serving micro-batcher drains.
//!
//! tokio is unavailable offline (DESIGN.md §2); the coordinator's
//! concurrency needs are CPU-bound fan-out + a bounded producer/consumer
//! pipeline, which std threads model directly and predictably.

mod coalesce;
mod pipeline;
mod pool;

pub use coalesce::CoalesceQueue;
pub use pipeline::{Prefetcher, PipelineStats};
pub use pool::ThreadPool;

/// Worker-count heuristic for CPU-bound fan-out (batched sampling walks,
/// sharded tree updates): the machine's available parallelism, capped —
/// kernel-tree walks are memory-bandwidth-bound well before 16 threads.
pub fn recommended_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Run `f(i)` for `i in 0..n` across `workers` threads (scoped; borrows
/// allowed). Results are returned in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map: missing slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_map_ordered_and_complete() {
        let got = parallel_map(100, 4, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_runs_on_multiple_threads() {
        // Not strictly guaranteed, but with 8 workers and a yield inside,
        // at least 2 distinct threads should participate.
        let ids = std::sync::Mutex::new(std::collections::HashSet::new());
        parallel_map(64, 8, |_| {
            std::thread::yield_now();
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() >= 2);
    }

    #[test]
    fn recommended_workers_is_sane() {
        let w = recommended_workers();
        assert!((1..=16).contains(&w));
    }

    #[test]
    fn parallel_map_empty() {
        let got: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn work_is_executed_exactly_once() {
        let counter = AtomicUsize::new(0);
        parallel_map(1000, 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }
}
