//! Fixed-size thread pool with a shared FIFO queue. Jobs are boxed
//! closures; `join()` blocks until the queue drains and all workers are
//! idle. Workers park on a condvar when idle.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<PoolState>,
    /// Signals workers that work arrived or shutdown began.
    work_cv: Condvar,
    /// Signals `join()` that the pool went idle.
    idle_cv: Condvar,
}

struct PoolState {
    jobs: VecDeque<Job>,
    in_flight: usize,
    shutdown: bool,
}

/// A minimal but correct thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "ThreadPool: need at least one thread");
        let shared = Arc::new(Shared {
            queue: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rfsm-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "ThreadPool: execute after shutdown");
        st.jobs.push_back(Box::new(job));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Block until all enqueued jobs have completed.
    pub fn join(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        while !st.jobs.is_empty() || st.in_flight > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    st.in_flight += 1;
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        job();
        let mut st = shared.queue.lock().unwrap();
        st.in_flight -= 1;
        if st.jobs.is_empty() && st.in_flight == 0 {
            shared.idle_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..256 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 256);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn reusable_across_waves() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _wave in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }
}
