//! Fixed-size thread pool with a shared FIFO queue. Jobs are boxed
//! closures; `join()` blocks until the queue drains and all workers are
//! idle. Workers park on a condvar when idle.
//!
//! [`ThreadPool::run_wave`] is the borrowing entry point: it executes a
//! batch of *non-`'static`* jobs on the persistent workers and blocks
//! until every one has completed — the zero-spawn replacement for
//! `std::thread::scope` on the serving hot path (ROADMAP: route
//! `fan_out_serve` through a persistent worker pool).

use std::any::Any;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<PoolState>,
    /// Signals workers that work arrived or shutdown began.
    work_cv: Condvar,
    /// Signals `join()` that the pool went idle.
    idle_cv: Condvar,
}

struct PoolState {
    jobs: VecDeque<Job>,
    in_flight: usize,
    shutdown: bool,
}

/// A minimal but correct thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "ThreadPool: need at least one thread");
        let shared = Arc::new(Shared {
            queue: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rfsm-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "ThreadPool: execute after shutdown");
        st.jobs.push_back(Box::new(job));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Block until all enqueued jobs have completed.
    pub fn join(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        while !st.jobs.is_empty() || st.in_flight > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Execute a wave of borrowing jobs on the persistent workers and
    /// block until every one has completed. This is the pool's
    /// `std::thread::scope` equivalent: jobs may capture `'scope`
    /// references because `run_wave` does not return until the last job
    /// has run, so no borrow outlives its owner.
    ///
    /// A panic inside a job is caught on the worker (pool threads never
    /// die) and re-raised *here* once the wave drains — the same
    /// propagation a scoped spawn's `join` gives, which is what the
    /// serving batcher's `catch_unwind` relies on. Only the first panic
    /// payload is kept.
    ///
    /// Waves from different caller threads may interleave in the shared
    /// FIFO; each caller waits only for its own jobs. Do **not** call
    /// `run_wave` from inside a pool job: the inner wave would wait for
    /// queue slots its own caller is occupying and can deadlock.
    pub fn run_wave<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        struct Wave {
            remaining: Mutex<usize>,
            done: Condvar,
            panic: Mutex<Option<Box<dyn Any + Send>>>,
        }
        impl Wave {
            fn wait(&self) {
                let mut rem = self.remaining.lock().unwrap();
                while *rem > 0 {
                    rem = self.done.wait(rem).unwrap();
                }
            }
        }
        let n_jobs = jobs.len();
        let wave = Arc::new(Wave {
            remaining: Mutex::new(n_jobs),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        // Every successfully-queued lifetime-erased job must complete
        // before run_wave returns OR unwinds — a queued job still
        // references the caller's stack. `execute` can panic mid-loop
        // (pool concurrently shut down), so the enqueue loop runs under
        // catch_unwind, never-queued jobs are cancelled out of the
        // count, and the wait happens on every exit path before the
        // panic (enqueue's or a job's) is re-raised.
        let mut enqueued = 0usize;
        let mut enqueue_panic: Option<Box<dyn Any + Send>> = None;
        for job in jobs {
            // SAFETY: all exit paths below wait until `remaining == 0`
            // before returning or resuming an unwind, i.e. until every
            // queued closure has finished running (a panic inside one is
            // caught, counted, payload stored), so every `'scope` borrow
            // strictly outlives its execution. Only the lifetime is
            // erased; the vtable/layout is unchanged.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let job_wave = Arc::clone(&wave);
            let worker_job = move || {
                let result =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                if let Err(p) = result {
                    let mut slot = job_wave.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                }
                let mut rem = job_wave.remaining.lock().unwrap();
                *rem -= 1;
                if *rem == 0 {
                    job_wave.done.notify_all();
                }
            };
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.execute(worker_job)
            })) {
                Ok(()) => enqueued += 1,
                Err(p) => {
                    enqueue_panic = Some(p);
                    break;
                }
            }
        }
        if enqueue_panic.is_some() {
            // Cancel the jobs that never made it into the queue (the one
            // that panicked in `execute` plus any unconsumed remainder —
            // `execute` asserts before pushing, so a panicking enqueue
            // queued nothing). Queued jobs were pushed before any
            // shutdown flag landed, so workers drain them and the wait
            // below terminates.
            let mut rem = wave.remaining.lock().unwrap();
            *rem -= n_jobs - enqueued;
            if *rem == 0 {
                wave.done.notify_all();
            }
        }
        wave.wait();
        if let Some(p) = enqueue_panic {
            std::panic::resume_unwind(p);
        }
        if let Some(p) = wave.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    st.in_flight += 1;
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        job();
        let mut st = shared.queue.lock().unwrap();
        st.in_flight -= 1;
        if st.jobs.is_empty() && st.in_flight == 0 {
            shared.idle_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..256 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 256);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn run_wave_borrows_and_blocks_until_done() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        {
            let slots: Vec<Mutex<&mut usize>> =
                out.iter_mut().map(Mutex::new).collect();
            let slots = &slots;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
                .map(|i| {
                    Box::new(move || {
                        **slots[i].lock().unwrap() = i * 3;
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_wave(jobs);
        }
        // run_wave returned ⇒ every borrow-writing job has completed.
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
    }

    #[test]
    fn run_wave_propagates_panics_and_keeps_workers_alive() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("wave job boom")),
            ];
            pool.run_wave(jobs);
        }));
        assert!(caught.is_err(), "job panic must re-raise in the caller");
        // The pool survives the panic and keeps serving new waves.
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_wave(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn run_wave_empty_is_a_no_op() {
        let pool = ThreadPool::new(1);
        pool.run_wave(Vec::new());
    }

    #[test]
    fn concurrent_waves_from_many_threads_complete_independently() {
        let pool = Arc::new(ThreadPool::new(3));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let counter = AtomicUsize::new(0);
                    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..50)
                        .map(|_| {
                            Box::new(|| {
                                counter.fetch_add(1, Ordering::Relaxed);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_wave(jobs);
                    assert_eq!(counter.load(Ordering::Relaxed), 50, "wave {t}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn reusable_across_waves() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _wave in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }
}
