//! Replica registry and consistent-hash class-shard map.
//!
//! The registry is the cluster's source of truth for *who owns what*:
//! a static list of replica endpoints (from `cluster.replicas`), a
//! per-replica health bit flipped by the router on failover, and a
//! consistent-hash ring that assigns every **global class id** to
//! exactly one replica.
//!
//! # The ring
//!
//! Each replica contributes `virtual_nodes` points on a `u64` ring,
//! hashed from `(replica_index, virtual_node)` — deliberately *not*
//! from the endpoint — so the partition depends only on the replica
//! count and vnode count. That independence is what makes
//! [`shard_partition`] possible: callers can pre-partition a vocabulary
//! and build each replica's sampler *before* any server exists, and the
//! registry connected to those servers later will agree on ownership
//! exactly.
//!
//! # Global vs. local ids
//!
//! Each replica's server numbers classes locally (dense ids from its
//! own `ClassStore`); the cluster speaks **global** ids. The registry
//! keeps the two maps in sync:
//!
//! - `local_of(global)` — dense local id on the owner, bound when the
//!   owner acks the add (or at [`ReplicaRegistry::seed`] time for the
//!   initial vocabulary);
//! - `global_of(replica, local)` — reverse map, used to translate ids
//!   in draws and top-k lists coming back from a replica.
//!
//! Ownership itself never consults these maps — it is pure ring
//! arithmetic on the global id — so the replication log can group a
//! retire by owner before the corresponding add has even been acked.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::transport::Endpoint;

/// SplitMix64 finalizer: the avalanche permutation used for both ring
/// points and class-id placement. Full 64-bit avalanche, so sequential
/// ids and sequential vnode indices land uniformly on the ring.
#[inline]
pub(crate) fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Domain separators so ring points and class placements can never
/// collide structurally.
const RING_SALT: u64 = 0x5249_4E47; // "RING"
const CLASS_SALT: u64 = 0x434C_4153; // "CLAS"

/// Build the sorted ring for `num_replicas` replicas with
/// `virtual_nodes` points each: `(point, replica_index)` ascending by
/// point. Deterministic in its two arguments alone.
fn build_ring(num_replicas: usize, virtual_nodes: usize) -> Vec<(u64, usize)> {
    let mut ring = Vec::with_capacity(num_replicas * virtual_nodes);
    for r in 0..num_replicas {
        for v in 0..virtual_nodes {
            let point =
                mix64(RING_SALT ^ ((r as u64) << 32) ^ v as u64);
            ring.push((point, r));
        }
    }
    ring.sort_unstable();
    ring
}

/// Owner of a global class id on a pre-built ring: first ring point at
/// or after the id's hash, wrapping at the top.
fn owner_on_ring(ring: &[(u64, usize)], global: u32) -> usize {
    let h = mix64(CLASS_SALT ^ global as u64);
    let i = ring.partition_point(|&(p, _)| p < h);
    ring[if i == ring.len() { 0 } else { i }].1
}

/// Partition `0..n_classes` across `num_replicas` replicas by the same
/// consistent-hash ring a [`ReplicaRegistry`] with the same shape would
/// build. Returns one ascending id list per replica (their union is the
/// full range). This is the *pre-serving* half of the ownership
/// contract: build replica `r`'s sampler over exactly
/// `partition[r]`'s rows, then [`ReplicaRegistry::seed`] with the same
/// partition, and router-side ownership lookups will match the data
/// placement class-for-class.
pub fn shard_partition(
    n_classes: usize,
    num_replicas: usize,
    virtual_nodes: usize,
) -> Vec<Vec<u32>> {
    assert!(num_replicas > 0, "cluster needs at least one replica");
    assert!(virtual_nodes > 0, "ring needs at least one vnode per replica");
    let ring = build_ring(num_replicas, virtual_nodes);
    let mut parts = vec![Vec::new(); num_replicas];
    for g in 0..n_classes as u32 {
        parts[owner_on_ring(&ring, g)].push(g);
    }
    parts
}

/// One cluster member: where it listens and whether the router still
/// considers it alive. Health starts `true` and is flipped down by the
/// router after a connection fails its retry; a down replica's shards
/// become unavailable (typed errors for point lookups, mass-renormalized
/// exclusion for draws) rather than silently wrong.
pub struct Replica {
    pub endpoint: Endpoint,
    healthy: AtomicBool,
}

impl Replica {
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    pub(crate) fn set_healthy(&self, on: bool) {
        self.healthy.store(on, Ordering::Release);
    }
}

/// Mutable id-translation state, one lock for both directions so an
/// add-ack binds them atomically.
struct IdState {
    /// Next unassigned global id (seeded past the initial vocabulary).
    next_global: u32,
    /// global id -> dense local id on its owner. Entries appear when
    /// the owner acks the add (seeded classes are bound up front) and
    /// disappear when a retire for the id is acked.
    local: HashMap<u32, u32>,
    /// replica -> local id -> global id. Append-only: retired slots
    /// keep their stale mapping, which is harmless because the server
    /// never returns a retired id in a draw.
    global: Vec<Vec<u32>>,
}

/// See the module docs: endpoints + health + ring + id maps.
pub struct ReplicaRegistry {
    replicas: Vec<Replica>,
    ring: Vec<(u64, usize)>,
    ids: Mutex<IdState>,
}

impl ReplicaRegistry {
    pub fn new(
        endpoints: Vec<Endpoint>,
        virtual_nodes: usize,
    ) -> ReplicaRegistry {
        assert!(!endpoints.is_empty(), "cluster needs at least one replica");
        assert!(virtual_nodes > 0, "ring needs at least one vnode per replica");
        let n = endpoints.len();
        ReplicaRegistry {
            replicas: endpoints
                .into_iter()
                .map(|endpoint| Replica {
                    endpoint,
                    healthy: AtomicBool::new(true),
                })
                .collect(),
            ring: build_ring(n, virtual_nodes),
            ids: Mutex::new(IdState {
                next_global: 0,
                local: HashMap::new(),
                global: vec![Vec::new(); n],
            }),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn replica(&self, r: usize) -> &Replica {
        &self.replicas[r]
    }

    /// Indices of replicas currently marked healthy.
    pub fn alive(&self) -> Vec<usize> {
        (0..self.replicas.len())
            .filter(|&r| self.replicas[r].is_healthy())
            .collect()
    }

    /// Which replica owns this global id (pure ring arithmetic — valid
    /// even before the id's add has been acked).
    pub fn owner_of(&self, global: u32) -> usize {
        owner_on_ring(&self.ring, global)
    }

    /// Bind the initial vocabulary: replica `r` was built over
    /// `partitions[r]` in order, so its dense local id `k` is
    /// `partitions[r][k]`. `partitions` must be the ownership partition
    /// this registry's ring produces (use [`shard_partition`] with the
    /// same replica and vnode counts); debug builds assert it.
    pub fn seed(&self, partitions: &[Vec<u32>]) {
        assert_eq!(partitions.len(), self.replicas.len());
        let mut ids = self.ids.lock().unwrap();
        for (r, part) in partitions.iter().enumerate() {
            for (local, &g) in part.iter().enumerate() {
                debug_assert_eq!(self.owner_of(g), r, "seed partition must match the ring");
                ids.local.insert(g, local as u32);
                ids.global[r].push(g);
                ids.next_global = ids.next_global.max(g + 1);
            }
        }
    }

    /// Allocate `count` fresh global ids and their ring owners. The ids
    /// are not bound to local ids yet — that happens at
    /// [`ReplicaRegistry::bind`] when the owner acks the add.
    pub fn assign_new(&self, count: usize) -> Vec<(u32, usize)> {
        let mut ids = self.ids.lock().unwrap();
        let base = ids.next_global;
        ids.next_global += count as u32;
        (0..count as u32)
            .map(|k| (base + k, self.owner_of(base + k)))
            .collect()
    }

    /// Record an add-ack: the owner assigned `locals[k]` to
    /// `globals[k]`. Called by the replication worker, in the replica's
    /// FIFO order, so a later retire of these globals resolves.
    pub fn bind(&self, replica: usize, globals: &[u32], locals: &[u32]) {
        debug_assert_eq!(globals.len(), locals.len());
        let mut ids = self.ids.lock().unwrap();
        for (&g, &l) in globals.iter().zip(locals) {
            ids.local.insert(g, l);
            let rev = &mut ids.global[replica];
            if rev.len() <= l as usize {
                rev.resize(l as usize + 1, u32::MAX);
            }
            rev[l as usize] = g;
        }
    }

    /// Drop retired globals from the forward map (retire-ack path).
    pub fn unbind(&self, globals: &[u32]) {
        let mut ids = self.ids.lock().unwrap();
        for g in globals {
            ids.local.remove(g);
        }
    }

    /// Dense local id of a global on its owner, if the add has been
    /// acked and the class not retired.
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.ids.lock().unwrap().local.get(&global).copied()
    }

    /// Global id behind a replica's local id (translating draw results).
    /// `None` only for local ids the registry has never seen — a
    /// protocol-level surprise, not a normal condition.
    pub fn global_of(&self, replica: usize, local: u32) -> Option<u32> {
        let ids = self.ids.lock().unwrap();
        match ids.global[replica].get(local as usize) {
            Some(&g) if g != u32::MAX => Some(g),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn endpoints(n: usize) -> Vec<Endpoint> {
        (0..n)
            .map(|i| Endpoint::Uds(PathBuf::from(format!("/tmp/r{i}.sock"))))
            .collect()
    }

    #[test]
    fn partition_is_deterministic_total_and_disjoint() {
        let a = shard_partition(1000, 3, 64);
        let b = shard_partition(1000, 3, 64);
        assert_eq!(a, b);
        let mut seen = vec![false; 1000];
        for part in &a {
            for &g in part {
                assert!(!seen[g as usize], "class {g} owned twice");
                seen[g as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every class must have an owner");
    }

    #[test]
    fn partition_is_roughly_balanced() {
        let parts = shard_partition(3000, 3, 64);
        for (r, part) in parts.iter().enumerate() {
            // Expected 1000 per replica; 64 vnodes keeps the spread well
            // within a factor of two.
            assert!(
                part.len() > 500 && part.len() < 1700,
                "replica {r} owns {} of 3000 classes",
                part.len()
            );
        }
    }

    #[test]
    fn registry_ring_matches_free_partition() {
        let reg = ReplicaRegistry::new(endpoints(3), 64);
        let parts = shard_partition(500, 3, 64);
        for (r, part) in parts.iter().enumerate() {
            for &g in part {
                assert_eq!(reg.owner_of(g), r);
            }
        }
    }

    #[test]
    fn seed_binds_both_directions() {
        let reg = ReplicaRegistry::new(endpoints(3), 64);
        let parts = shard_partition(100, 3, 64);
        reg.seed(&parts);
        for (r, part) in parts.iter().enumerate() {
            for (local, &g) in part.iter().enumerate() {
                assert_eq!(reg.local_of(g), Some(local as u32));
                assert_eq!(reg.global_of(r, local as u32), Some(g));
            }
        }
        // Fresh ids start past the seeded range.
        let fresh = reg.assign_new(4);
        assert_eq!(fresh[0].0, 100);
        assert_eq!(fresh[3].0, 103);
        for &(g, owner) in &fresh {
            assert_eq!(owner, reg.owner_of(g));
            assert_eq!(reg.local_of(g), None, "unacked adds are unbound");
        }
    }

    #[test]
    fn bind_and_unbind_track_churn() {
        let reg = ReplicaRegistry::new(endpoints(2), 32);
        let parts = shard_partition(10, 2, 32);
        reg.seed(&parts);
        let assigned = reg.assign_new(2);
        let (g0, r0) = assigned[0];
        // Owner acks with the next dense local ids on that replica.
        let base = parts[r0].len() as u32;
        reg.bind(r0, &[g0], &[base]);
        assert_eq!(reg.local_of(g0), Some(base));
        assert_eq!(reg.global_of(r0, base), Some(g0));
        reg.unbind(&[g0]);
        assert_eq!(reg.local_of(g0), None);
        // Reverse entry is intentionally stale-but-present; the server
        // never returns a retired local id.
        assert_eq!(reg.global_of(r0, base), Some(g0));
    }

    #[test]
    fn health_bit_gates_alive_set() {
        let reg = ReplicaRegistry::new(endpoints(3), 8);
        assert_eq!(reg.alive(), vec![0, 1, 2]);
        reg.replica(1).set_healthy(false);
        assert_eq!(reg.alive(), vec![0, 2]);
        reg.replica(1).set_healthy(true);
        assert_eq!(reg.alive(), vec![0, 1, 2]);
    }
}
