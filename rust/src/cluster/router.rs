//! Consistent-hash cluster router: the client-facing half of the L5
//! serving cluster.
//!
//! A [`ClusterRouter`] fronts the same request surface as a single
//! [`TransportClient`] — sample / probability / top-k plus vocabulary
//! churn — but fans every logical request out across the replica set by
//! shard ownership and merges the sub-answers exactly:
//!
//! - **sample** runs in two phases. Phase 1 ships one `MASS` frame per
//!   replica (batched into a wire-v3 wave per replica for bursts) and
//!   learns each replica's total proposal mass `M_r` at the query.
//!   Phase 2 splits the `m` requested draws across replicas with a
//!   router-side RNG seeded from the request seed — slot `j` picks
//!   replica `r` with probability `M_r / ΣM` — and ships one `SAMPLE`
//!   sub-request per chosen replica with a per-replica derived seed.
//!   The merged draw consumes each replica's (conditional) draws in
//!   slot-pick order and rescales probabilities by `M_r / ΣM`, so the
//!   cluster marginal is *exactly* the union distribution: `(M_r/ΣM) ·
//!   q_r(i) = mass(i)/ΣM`. This is the distributed analogue of the
//!   in-process sharded tree's two-level pick. Total tree-walk work is
//!   still `m` draws — split, not duplicated — which is what lets the
//!   cluster beat one replica on throughput.
//! - **probability** is an owner lookup: ring → owner replica → local
//!   id → `q_r(i) · M_r / ΣM`.
//! - **top-k** fans to every live replica, rescales each list by
//!   `M_r / ΣM`, and merge-sorts (score descending, global id as the
//!   tie-break) before truncating to `k`.
//! - **churn** (add/retire) is appended to the epoch-sequenced
//!   replication log and applied asynchronously; see
//!   [`super::replication`].
//!
//! # Determinism
//!
//! For a fixed cluster shape (replica count, vnodes), health set, and
//! replica epochs, a request seed fully determines the merged draw:
//! the split RNG, the per-replica sub-seeds, and the replicas' own
//! walks are all seed-derived. Cluster draws are *reproducible*, but
//! not byte-identical to a single node serving the union vocabulary —
//! the draw sequence differs; the distribution does not (the
//! integration suite checks the χ² consistency of exactly that).
//!
//! # Failover and hedging
//!
//! Every per-replica sub-batch send/recv gets one
//! reconnect-and-replay on a connection-closing error (all routed
//! sub-requests are idempotent reads — churn never passes through
//! here). A second failure marks the replica down; sample and top-k
//! re-route the affected items over the survivors with renormalized
//! masses, while probability for classes owned by the dead replica
//! fails with a typed [`ClusterError::ReplicaDown`]. With hedging
//! enabled, the first wait uses a p99-derived deadline instead of the
//! full request timeout: when it trips, the router abandons the
//! straggler's connection (a timed-out read may sit mid-frame — the
//! connection is unusable by construction) and replays the identical
//! sub-batch on a fresh one. Same seeds, same answers — the hedge can
//! win time but never change results, and the logical request is
//! counted once no matter how many copies raced.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::registry::{mix64, ReplicaRegistry};
use super::replication::LogShared;
use crate::linalg::Matrix;
use crate::metrics::live::{LiveHistogram, LiveRegistry, ShardedCounter};
use crate::rng::Rng;
use crate::sampler::NegativeDraw;
use crate::serving::ServeReply;
use crate::transport::{ProtocolError, Request, Response, TransportClient};

/// Typed cluster failure surface (the "graceful degradation" half of
/// the router contract: a dead replica yields these, never a hang or a
/// silently wrong merge).
#[derive(Debug)]
pub enum ClusterError {
    /// A transport-level failure that survived the retry budget.
    Protocol(ProtocolError),
    /// No replica is currently healthy.
    NoReplicas,
    /// The class id is not (or not yet) bound on its owner — either
    /// never added, already retired, or its add is still in the
    /// replication log.
    UnknownClass(u32),
    /// The class's owner replica is marked down; point lookups cannot
    /// be re-routed (ownership is exclusive).
    ReplicaDown(usize),
    /// A replica died while this request was in flight. Internal
    /// re-route marker: `query_burst` retries such items over the
    /// survivors, so callers only see it when no retry round is left.
    ReplicaLost(usize),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Protocol(e) => write!(f, "cluster transport: {e}"),
            ClusterError::NoReplicas => write!(f, "no healthy replicas"),
            ClusterError::UnknownClass(g) => {
                write!(f, "class {g} is not bound on any replica")
            }
            ClusterError::ReplicaDown(r) => {
                write!(f, "owner replica {r} is down")
            }
            ClusterError::ReplicaLost(r) => {
                write!(f, "replica {r} died mid-request")
            }
        }
    }
}

impl std::error::Error for ClusterError {}

impl From<ProtocolError> for ClusterError {
    fn from(e: ProtocolError) -> Self {
        ClusterError::Protocol(e)
    }
}

/// One logical request against the cluster's global id space.
#[derive(Clone, Debug)]
pub enum ClusterQuery {
    Sample { h: Vec<f32>, m: usize, seed: u64 },
    Probability { h: Vec<f32>, class: u32 },
    TopK { h: Vec<f32>, k: usize },
}

impl ClusterQuery {
    fn h(&self) -> &[f32] {
        match self {
            ClusterQuery::Sample { h, .. }
            | ClusterQuery::Probability { h, .. }
            | ClusterQuery::TopK { h, .. } => h,
        }
    }
}

/// Merged cluster answer, global-id space throughout.
#[derive(Debug)]
pub enum ClusterReply {
    Sample(ServeReply),
    Probability { q: f64, epoch: u64 },
    TopK { items: Vec<(u32, f64)>, epoch: u64 },
}

/// Per-item phase-2 plan (what was sent where, and how to merge it).
enum Plan {
    /// Slot-pick order of the split; merged draw replays it.
    Sample { picks: Vec<usize>, total: f64 },
    Prob { owner: usize, total: f64 },
    TopK { k: usize, total: f64 },
    /// Item already resolved (error before phase 2).
    Done,
}

/// Minimum sub-wave latency samples before hedging arms, and the
/// multiple of p99 used as the hedge deadline.
const HEDGE_MIN_SAMPLES: u64 = 32;
const HEDGE_P99_MULTIPLE: u64 = 3;
const HEDGE_FLOOR: Duration = Duration::from_millis(1);

/// See the module docs. One router per client thread (it owns its
/// per-replica connections, like a `TransportClient` owns its socket);
/// routers made from the same [`super::Cluster`] share the registry,
/// replication log, and metrics.
pub struct ClusterRouter {
    registry: Arc<ReplicaRegistry>,
    log: Arc<LogShared>,
    conns: Vec<Option<TransportClient>>,
    timeout: Duration,
    hedge: bool,
    requests: Arc<ShardedCounter>,
    hedges_fired: Arc<ShardedCounter>,
    hedges_won: Arc<ShardedCounter>,
    failovers: Arc<ShardedCounter>,
    subwave: Arc<LiveHistogram>,
}

impl ClusterRouter {
    pub(crate) fn new(
        registry: Arc<ReplicaRegistry>,
        log: Arc<LogShared>,
        metrics: &LiveRegistry,
        timeout: Duration,
        hedge: bool,
    ) -> ClusterRouter {
        let n = registry.len();
        ClusterRouter {
            registry,
            log,
            conns: (0..n).map(|_| None).collect(),
            timeout,
            hedge,
            requests: metrics.counter("cluster.requests"),
            hedges_fired: metrics.counter("cluster.hedges_fired"),
            hedges_won: metrics.counter("cluster.hedges_won"),
            failovers: metrics.counter("cluster.failovers"),
            subwave: metrics.histogram("cluster.subwave"),
        }
    }

    // -- single-request surface (TransportClient-shaped) ----------------

    /// Draw `m` classes from the cluster-wide proposal distribution;
    /// ids and probabilities are global. See the module docs for the
    /// two-phase split.
    pub fn sample(
        &mut self,
        h: &[f32],
        m: usize,
        seed: u64,
    ) -> Result<ServeReply, ClusterError> {
        let q = ClusterQuery::Sample { h: h.to_vec(), m, seed };
        match self.query_burst(std::slice::from_ref(&q), false).pop().unwrap()? {
            ClusterReply::Sample(reply) => Ok(reply),
            _ => Err(ProtocolError::Malformed("reply kind mismatch").into()),
        }
    }

    /// Cluster-wide `q(class | h)` for a global class id.
    pub fn probability(
        &mut self,
        h: &[f32],
        class: u32,
    ) -> Result<(f64, u64), ClusterError> {
        let q = ClusterQuery::Probability { h: h.to_vec(), class };
        match self.query_burst(std::slice::from_ref(&q), false).pop().unwrap()? {
            ClusterReply::Probability { q, epoch } => Ok((q, epoch)),
            _ => Err(ProtocolError::Malformed("reply kind mismatch").into()),
        }
    }

    /// Cluster-wide top-k (global ids, globally-normalized scores).
    pub fn top_k(
        &mut self,
        h: &[f32],
        k: usize,
    ) -> Result<(Vec<(u32, f64)>, u64), ClusterError> {
        let q = ClusterQuery::TopK { h: h.to_vec(), k };
        match self.query_burst(std::slice::from_ref(&q), false).pop().unwrap()? {
            ClusterReply::TopK { items, epoch } => Ok((items, epoch)),
            _ => Err(ProtocolError::Malformed("reply kind mismatch").into()),
        }
    }

    /// Append new classes through the replication log. Returns the
    /// assigned **global** ids and the log sequence number immediately;
    /// owners converge asynchronously (flush the cluster to wait).
    pub fn add_classes(&mut self, embeddings: &Matrix) -> (Vec<u32>, u64) {
        self.log.append_add(embeddings)
    }

    /// Retire global classes through the replication log; returns the
    /// log sequence number.
    pub fn retire_classes(&mut self, globals: &[u32]) -> u64 {
        self.log.append_retire(globals)
    }

    // -- burst surface ---------------------------------------------------

    /// Run a burst of logical requests through the two-phase fan-out,
    /// batching each replica's sub-requests into wire-v3 wave frames
    /// when `wave` is set (two round-trips per burst instead of two per
    /// request). Results are item-aligned with `queries`. Items that
    /// lose a replica mid-flight are re-routed over the survivors;
    /// keep bursts at or below [`crate::transport::MAX_IN_FLIGHT`]` / 2`
    /// so a replica's sub-batch can never trip the server's shed cap.
    pub fn query_burst(
        &mut self,
        queries: &[ClusterQuery],
        wave: bool,
    ) -> Vec<Result<ClusterReply, ClusterError>> {
        // Logical requests count once, however many hedges/retries the
        // burst spends serving them — the invariant the stats
        // reconciliation test leans on.
        self.requests.add(queries.len() as u64);
        let mut out = self.burst_round(queries, wave);
        // Re-route items that lost their replica mid-round. Every extra
        // round implies at least one replica newly died, so the depth
        // is bounded by the replica count.
        for _ in 0..self.registry.len() {
            let failed: Vec<usize> = out
                .iter()
                .enumerate()
                .filter(|(_, r)| {
                    matches!(r, Err(ClusterError::ReplicaLost(_)))
                })
                .map(|(i, _)| i)
                .collect();
            if failed.is_empty() || self.registry.alive().is_empty() {
                break;
            }
            let again: Vec<ClusterQuery> =
                failed.iter().map(|&i| queries[i].clone()).collect();
            for (slot, res) in
                failed.into_iter().zip(self.burst_round(&again, wave))
            {
                out[slot] = res;
            }
        }
        out
    }

    fn burst_round(
        &mut self,
        queries: &[ClusterQuery],
        wave: bool,
    ) -> Vec<Result<ClusterReply, ClusterError>> {
        let nrep = self.registry.len();
        let w = queries.len();
        let alive = self.registry.alive();
        if alive.is_empty() {
            return (0..w).map(|_| Err(ClusterError::NoReplicas)).collect();
        }

        // Phase 1: per-replica total proposal mass at every query point.
        let mut mass_batches: Vec<Vec<Request>> = vec![Vec::new(); nrep];
        for &r in &alive {
            mass_batches[r] = queries
                .iter()
                .map(|q| Request::Mass { h: q.h().to_vec() })
                .collect();
        }
        let mass_resps = self.fan_out(mass_batches, wave);
        let mut masses = vec![vec![0.0f64; nrep]; w];
        for (r, resps) in mass_resps.into_iter().enumerate() {
            let Some(resps) = resps else { continue };
            for (i, resp) in resps.into_iter().enumerate() {
                if let Response::Mass { mass, .. } = resp {
                    masses[i][r] = mass.max(0.0);
                }
            }
        }

        // Phase 2: plan and ship per-replica sub-requests.
        let mut out: Vec<Option<Result<ClusterReply, ClusterError>>> =
            (0..w).map(|_| None).collect();
        let mut plans: Vec<Plan> = Vec::with_capacity(w);
        let mut batches: Vec<Vec<Request>> = vec![Vec::new(); nrep];
        // Item index behind each sub-request, batch-order per replica.
        let mut subs: Vec<Vec<usize>> = vec![Vec::new(); nrep];
        for (i, q) in queries.iter().enumerate() {
            let total: f64 = masses[i].iter().sum();
            match q {
                ClusterQuery::Sample { h, m, seed } => {
                    if total <= 0.0 {
                        out[i] = Some(Err(ProtocolError::Malformed(
                            "cluster proposal mass is zero",
                        )
                        .into()));
                        plans.push(Plan::Done);
                        continue;
                    }
                    let (counts, picks) = split_draws(&masses[i], *m, *seed);
                    for (r, &c) in counts.iter().enumerate() {
                        if c > 0 {
                            batches[r].push(Request::Sample {
                                h: h.clone(),
                                m: c,
                                seed: sub_seed(*seed, r),
                            });
                            subs[r].push(i);
                        }
                    }
                    plans.push(Plan::Sample { picks, total });
                }
                ClusterQuery::Probability { h, class } => {
                    let owner = self.registry.owner_of(*class);
                    if !self.registry.replica(owner).is_healthy() {
                        out[i] = Some(Err(ClusterError::ReplicaDown(owner)));
                        plans.push(Plan::Done);
                        continue;
                    }
                    let Some(local) = self.registry.local_of(*class) else {
                        out[i] = Some(Err(ClusterError::UnknownClass(*class)));
                        plans.push(Plan::Done);
                        continue;
                    };
                    batches[owner].push(Request::Probability {
                        h: h.clone(),
                        class: local,
                    });
                    subs[owner].push(i);
                    plans.push(Plan::Prob { owner, total });
                }
                ClusterQuery::TopK { h, k } => {
                    for &r in &alive {
                        if masses[i][r] > 0.0 {
                            batches[r].push(Request::TopK {
                                h: h.clone(),
                                k: *k as u32,
                            });
                            subs[r].push(i);
                        }
                    }
                    plans.push(Plan::TopK { k: *k, total });
                }
            }
        }
        let sub_resps = self.fan_out(batches, wave);

        // Regroup sub-responses by item.
        let mut per_item: Vec<Vec<(usize, Option<Response>)>> =
            (0..w).map(|_| Vec::new()).collect();
        for (r, resps) in sub_resps.into_iter().enumerate() {
            match resps {
                Some(resps) => {
                    for (&i, resp) in subs[r].iter().zip(resps) {
                        per_item[i].push((r, Some(resp)));
                    }
                }
                None => {
                    for &i in &subs[r] {
                        per_item[i].push((r, None));
                    }
                }
            }
        }

        // Phase 3: merge.
        for (i, plan) in plans.into_iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            let entries = std::mem::take(&mut per_item[i]);
            out[i] = Some(self.merge_item(plan, entries, &masses[i]));
        }
        out.into_iter().map(|o| o.expect("every item planned")).collect()
    }

    /// Merge one item's sub-responses according to its plan.
    fn merge_item(
        &self,
        plan: Plan,
        entries: Vec<(usize, Option<Response>)>,
        masses: &[f64],
    ) -> Result<ClusterReply, ClusterError> {
        // A dead sub-replica poisons the item (the burst loop re-routes
        // it); a Response::Error poisons it terminally.
        let mut resolved = Vec::with_capacity(entries.len());
        for (r, resp) in entries {
            match resp {
                None => return Err(ClusterError::ReplicaLost(r)),
                Some(Response::Error { code, message }) => {
                    return Err(ProtocolError::Remote { code, message }.into())
                }
                Some(resp) => resolved.push((r, resp)),
            }
        }
        match plan {
            Plan::Done => unreachable!("Done items never reach merge"),
            Plan::Sample { picks, total, .. } => {
                let nrep = masses.len();
                let mut draws: Vec<Option<(VecDeque<u32>, VecDeque<f64>)>> =
                    (0..nrep).map(|_| None).collect();
                let mut epoch = 0u64;
                for (r, resp) in resolved {
                    let Response::Sample { epoch: e, ids, probs } = resp
                    else {
                        return Err(ProtocolError::Malformed(
                            "response kind mismatch",
                        )
                        .into());
                    };
                    epoch = epoch.max(e);
                    draws[r] = Some((ids.into(), probs.into()));
                }
                let mut ids = Vec::with_capacity(picks.len());
                let mut probs = Vec::with_capacity(picks.len());
                for &r in &picks {
                    let Some((lids, lprobs)) = draws[r].as_mut() else {
                        return Err(ProtocolError::Malformed(
                            "replica returned no draw for its slots",
                        )
                        .into());
                    };
                    let (Some(local), Some(q)) =
                        (lids.pop_front(), lprobs.pop_front())
                    else {
                        return Err(ProtocolError::Malformed(
                            "replica under-delivered draws",
                        )
                        .into());
                    };
                    let Some(global) = self.registry.global_of(r, local)
                    else {
                        return Err(ProtocolError::Malformed(
                            "replica returned an unmapped local id",
                        )
                        .into());
                    };
                    ids.push(global);
                    probs.push(q * masses[r] / total);
                }
                Ok(ClusterReply::Sample(ServeReply {
                    draw: NegativeDraw { ids, probs },
                    epoch,
                }))
            }
            Plan::Prob { owner, total } => {
                let Some((_, Response::Probability { epoch, q })) =
                    resolved.into_iter().next()
                else {
                    return Err(ProtocolError::Malformed(
                        "response kind mismatch",
                    )
                    .into());
                };
                Ok(ClusterReply::Probability {
                    q: q * masses[owner] / total,
                    epoch,
                })
            }
            Plan::TopK { k, total } => {
                let mut merged: Vec<(u32, f64)> = Vec::new();
                let mut epoch = 0u64;
                for (r, resp) in resolved {
                    let Response::TopK { epoch: e, items } = resp else {
                        return Err(ProtocolError::Malformed(
                            "response kind mismatch",
                        )
                        .into());
                    };
                    epoch = epoch.max(e);
                    for (local, score) in items {
                        let Some(global) = self.registry.global_of(r, local)
                        else {
                            return Err(ProtocolError::Malformed(
                                "replica returned an unmapped local id",
                            )
                            .into());
                        };
                        merged.push((global, score * masses[r] / total));
                    }
                }
                Ok(ClusterReply::TopK {
                    items: merge_topk(merged, k),
                    epoch,
                })
            }
        }
    }

    // -- transport plumbing ----------------------------------------------

    fn conn(
        &mut self,
        r: usize,
    ) -> Result<&mut TransportClient, ProtocolError> {
        if self.conns[r].is_none() {
            let endpoint = &self.registry.replica(r).endpoint;
            self.conns[r] = Some(TransportClient::connect_endpoint_timeout(
                endpoint,
                self.timeout,
            )?);
        }
        Ok(self.conns[r].as_mut().unwrap())
    }

    fn mark_down(&mut self, r: usize) {
        self.conns[r] = None;
        self.registry.replica(r).set_healthy(false);
        self.failovers.incr();
    }

    /// Ship every replica's batch before reading any reply — the
    /// replicas overlap their compute while the router is still
    /// writing, which is the cluster's whole parallelism story on a
    /// synchronous client. Then collect per replica with
    /// hedge/failover. `None` marks a replica that died (and has been
    /// marked down); per-sub `Response::Error`s pass through untouched.
    fn fan_out(
        &mut self,
        batches: Vec<Vec<Request>>,
        wave: bool,
    ) -> Vec<Option<Vec<Response>>> {
        let nrep = batches.len();
        let mut bases: Vec<Option<u64>> = vec![None; nrep];
        for (r, batch) in batches.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            match self.send_with_retry(r, batch, wave) {
                Ok(base) => bases[r] = Some(base),
                Err(_) => self.mark_down(r),
            }
        }
        let mut out: Vec<Option<Vec<Response>>> =
            (0..nrep).map(|_| None).collect();
        for (r, batch) in batches.iter().enumerate() {
            let Some(base) = bases[r] else { continue };
            out[r] = self.collect_with_hedge(r, base, batch, wave);
        }
        out
    }

    /// Write one replica's sub-batch; a connection-closing failure gets
    /// one fresh connection (with fresh request ids) before giving up.
    fn send_with_retry(
        &mut self,
        r: usize,
        reqs: &[Request],
        wave: bool,
    ) -> Result<u64, ProtocolError> {
        match self.try_send(r, reqs, wave) {
            Ok(base) => Ok(base),
            Err(e) if e.closes_connection() => {
                self.conns[r] = None;
                self.try_send(r, reqs, wave)
            }
            Err(e) => Err(e),
        }
    }

    fn try_send(
        &mut self,
        r: usize,
        reqs: &[Request],
        wave: bool,
    ) -> Result<u64, ProtocolError> {
        let client = self.conn(r)?;
        let base = client.alloc_ids(reqs.len());
        let items: Vec<(u64, Request)> = reqs
            .iter()
            .enumerate()
            .map(|(i, q)| (base + i as u64, q.clone()))
            .collect();
        client.send_batch(&items, wave)?;
        Ok(base)
    }

    /// Collect one replica's sub-batch. With hedging armed, the first
    /// wait runs under a p99-derived deadline; tripping it abandons the
    /// straggler connection and replays the identical (same-seed, hence
    /// same-answer) sub-batch on a fresh one — the duplicate that
    /// finishes is the one that counts, and it can only be one of them
    /// because the abandoned socket is closed before the replay is
    /// sent. Without hedging the same replay happens once on any
    /// connection-closing error; a second failure marks the replica
    /// down and returns `None`.
    fn collect_with_hedge(
        &mut self,
        r: usize,
        base: u64,
        reqs: &[Request],
        wave: bool,
    ) -> Option<Vec<Response>> {
        let hedge_after = self.hedge_delay();
        if let (Some(d), Some(conn)) = (hedge_after, self.conns[r].as_ref()) {
            let _ = conn.set_read_timeout(Some(d));
        }
        let t0 = Instant::now();
        let resps = match self.try_recv(r, base, reqs.len()) {
            Ok(resps) => {
                if hedge_after.is_some() {
                    if let Some(conn) = self.conns[r].as_ref() {
                        let _ = conn.set_read_timeout(Some(self.timeout));
                    }
                }
                Some(resps)
            }
            Err(e) => {
                let hedged = hedge_after.is_some()
                    && matches!(e, ProtocolError::Timeout);
                if hedged {
                    self.hedges_fired.incr();
                }
                self.conns[r] = None;
                let replay = match self.try_send(r, reqs, wave) {
                    Ok(b) => self.try_recv(r, b, reqs.len()),
                    Err(e) => Err(e),
                };
                match replay {
                    Ok(resps) => {
                        if hedged {
                            self.hedges_won.incr();
                        }
                        Some(resps)
                    }
                    Err(_) => {
                        self.mark_down(r);
                        None
                    }
                }
            }
        };
        if resps.is_some() {
            self.subwave.record(t0.elapsed());
        }
        resps
    }

    /// Read `n` responses for ids `base..base+n` off replica `r`,
    /// re-ordering by id (the server may interleave wave packing).
    fn try_recv(
        &mut self,
        r: usize,
        base: u64,
        n: usize,
    ) -> Result<Vec<Response>, ProtocolError> {
        let client =
            self.conns[r].as_mut().expect("collect follows a send");
        let mut got: Vec<Option<Response>> = (0..n).map(|_| None).collect();
        let mut remaining = n;
        while remaining > 0 {
            let (id, resp) = client.recv_one()?;
            let idx = id
                .checked_sub(base)
                .filter(|&i| i < n as u64)
                .ok_or(ProtocolError::IdMismatch { sent: base, got: id })?
                as usize;
            if got[idx].replace(resp).is_none() {
                remaining -= 1;
            }
        }
        Ok(got.into_iter().map(|o| o.expect("counted")).collect())
    }

    /// Hedge deadline: 3× the observed sub-wave p99, floored at 1ms,
    /// capped at the request timeout. `None` until enough latency
    /// samples exist (hedging off a cold histogram would fire blind)
    /// or when hedging is disabled.
    fn hedge_delay(&self) -> Option<Duration> {
        if !self.hedge || self.subwave.count() < HEDGE_MIN_SAMPLES {
            return None;
        }
        let p99 = self.subwave.quantile_ns(0.99);
        let d = Duration::from_nanos(
            p99.saturating_mul(HEDGE_P99_MULTIPLE)
                .max(HEDGE_FLOOR.as_nanos() as u64),
        );
        Some(d.min(self.timeout))
    }
}

/// Split `m` draw slots across replicas proportionally to their masses,
/// deterministically in `seed`. Returns per-replica counts and the
/// slot-order pick sequence (the merge replays it so draw order is
/// reproducible). Zero-mass replicas are never picked.
fn split_draws(masses: &[f64], m: usize, seed: u64) -> (Vec<u32>, Vec<usize>) {
    let total: f64 = masses.iter().sum();
    debug_assert!(total > 0.0);
    let mut rng = Rng::seeded(mix64(seed ^ SPLIT_SALT));
    let mut counts = vec![0u32; masses.len()];
    let mut picks = Vec::with_capacity(m);
    for _ in 0..m {
        let mut x = rng.f64() * total;
        let mut pick = 0usize;
        for (r, &w) in masses.iter().enumerate() {
            if w <= 0.0 {
                continue;
            }
            pick = r;
            x -= w;
            if x <= 0.0 {
                break;
            }
        }
        // f64 rounding can leave x marginally positive after the last
        // positive-mass replica; `pick` already holds it.
        counts[pick] += 1;
        picks.push(pick);
    }
    (counts, picks)
}

/// Per-replica sub-seed: derived, stable, and distinct per replica so
/// replicas never walk correlated streams for one logical request.
fn sub_seed(seed: u64, replica: usize) -> u64 {
    mix64(seed ^ SUB_SALT ^ ((replica as u64) << 48))
}

const SPLIT_SALT: u64 = 0x53504C49_54; // "SPLIT"
const SUB_SALT: u64 = 0x5355_4253; // "SUBS"

/// Merge-sort a pooled top-k candidate list: score descending, global
/// id ascending as the tie-break (deterministic across replica
/// orderings), truncated to `k`.
fn merge_topk(mut pool: Vec<(u32, f64)>, k: usize) -> Vec<(u32, f64)> {
    pool.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    pool.truncate(k);
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_deterministic_and_complete() {
        let masses = vec![3.0, 0.0, 1.0];
        let (c1, p1) = split_draws(&masses, 1000, 42);
        let (c2, p2) = split_draws(&masses, 1000, 42);
        assert_eq!(c1, c2);
        assert_eq!(p1, p2);
        assert_eq!(c1.iter().sum::<u32>(), 1000);
        assert_eq!(p1.len(), 1000);
        assert_eq!(c1[1], 0, "zero-mass replica must never be picked");
        // 3:1 mass ratio → roughly 750/250.
        assert!(c1[0] > 650 && c1[0] < 850, "got {}", c1[0]);
        // Counts and picks agree.
        let mut recount = vec![0u32; 3];
        for &r in &p1 {
            recount[r] += 1;
        }
        assert_eq!(recount, c1);
    }

    #[test]
    fn split_varies_with_seed() {
        let masses = vec![1.0, 1.0];
        let (_, p1) = split_draws(&masses, 64, 1);
        let (_, p2) = split_draws(&masses, 64, 2);
        assert_ne!(p1, p2, "different seeds must split differently");
    }

    #[test]
    fn sub_seeds_are_distinct_per_replica() {
        let s: Vec<u64> = (0..8).map(|r| sub_seed(977, r)).collect();
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_ne!(s[i], s[j]);
            }
            assert_ne!(s[i], 977, "sub-seed must not echo the request seed");
        }
    }

    #[test]
    fn topk_merge_sorts_and_breaks_ties_by_id() {
        let pool = vec![
            (7, 0.25),
            (1, 0.5),
            (9, 0.25),
            (3, 0.125),
            (2, 0.25),
        ];
        let merged = merge_topk(pool, 4);
        assert_eq!(merged, vec![(1, 0.5), (2, 0.25), (7, 0.25), (9, 0.25)]);
    }
}
