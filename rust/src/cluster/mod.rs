//! L5: replicated serving cluster — registry, consistent-hash router,
//! epoch-sequenced churn replication, and hedged failover.
//!
//! One serving stack (L3.5 batcher behind an L4 transport server)
//! holds one shard of the class universe; this module makes **several
//! of them answer as one**:
//!
//! ```text
//!            ClusterRouter (one per client thread)
//!           /       |        \            sample: MASS fan-out, then
//!   TransportClient conns     \           mass-weighted split draws
//!         /         |          \          top_k: fan + rescale + merge
//!    replica 0   replica 1   replica 2    probability: owner lookup
//!    (shard A)   (shard B)   (shard C)
//!         \          |          /
//!          per-replica admin conns
//!           \        |        /
//!            ReplicationLog worker (one per Cluster)
//!                    |
//!            ReplicaRegistry: ring + health + global<->local ids
//! ```
//!
//! - [`registry`] owns membership: the static endpoint list
//!   (`cluster.replicas`), per-replica health, the consistent-hash
//!   ring that maps every global class id to exactly one owner, and
//!   the global↔local id translation. [`shard_partition`] exposes the
//!   ring's partition *before* any server exists, so callers can
//!   build each replica's sampler over exactly its shard.
//! - [`router`] is the client surface: the same sample / probability /
//!   top-k API as a single [`crate::transport::TransportClient`], with
//!   every answer merged exactly (mass-weighted — see the router docs
//!   for the math) and every failure typed.
//! - [`replication`] carries churn: adds/retires enter through the
//!   router, get a cluster-wide sequence number, and drain to owner
//!   replicas over dedicated admin connections with per-replica acked
//!   cursors; lag is observable, and [`Cluster::flush`] awaits
//!   convergence. Ops abandoned on a dead replica are parked with
//!   their sequence ranges recorded, and a replica restarted from a
//!   durable snapshot rejoins through [`Cluster::bootstrap_replica`] —
//!   snapshot state plus replayed log tail, zero lost churn.
//!
//! Everything is std-only and sits strictly *above* the transport: no
//! server-side changes beyond the wire-v3 `MASS` frame exist for the
//! cluster's benefit, so any wire-v3 server — including one started by
//! an older build — can be a replica.

pub mod registry;
pub mod replication;
pub mod router;

pub use registry::{shard_partition, Replica, ReplicaRegistry};
pub use router::{ClusterError, ClusterQuery, ClusterReply, ClusterRouter};

use std::sync::Arc;
use std::time::Duration;

use crate::config::ClusterConfig;
use crate::json::Json;
use crate::metrics::live::LiveRegistry;
use crate::transport::Endpoint;
use replication::ReplicationLog;

/// Tunables for [`Cluster::connect`], mirroring the `cluster.*` config
/// section.
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// Per-replica connect and read deadline (`cluster.request_timeout_ms`).
    pub request_timeout: Duration,
    /// Duplicate straggling sub-waves after a p99-derived delay
    /// (`cluster.hedge`).
    pub hedge: bool,
    /// Ring points per replica (`cluster.virtual_nodes`).
    pub virtual_nodes: usize,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        let d = ClusterConfig::default();
        ClusterOptions {
            request_timeout: Duration::from_millis(d.request_timeout_ms),
            hedge: d.hedge,
            virtual_nodes: d.virtual_nodes,
        }
    }
}

impl ClusterOptions {
    /// Options from a validated config section (endpoint parsing stays
    /// with the caller — `cluster.replicas` is a comma-separated list
    /// of endpoint specs, see [`Endpoint::parse`]).
    pub fn from_config(cfg: &ClusterConfig) -> ClusterOptions {
        ClusterOptions {
            request_timeout: Duration::from_millis(cfg.request_timeout_ms),
            hedge: cfg.hedge,
            virtual_nodes: cfg.virtual_nodes,
        }
    }
}

/// Parse a `cluster.replicas`-style comma-separated endpoint list.
pub fn parse_replicas(spec: &str) -> std::io::Result<Vec<Endpoint>> {
    spec.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(Endpoint::parse)
        .collect()
}

/// The cluster handle: registry + replication log + shared metrics.
/// One per process (or test); cheap [`ClusterRouter`] handles are made
/// per client thread with [`Cluster::client`]. Dropping the cluster
/// stops the replication worker (flush first if queued churn must
/// land).
pub struct Cluster {
    registry: Arc<ReplicaRegistry>,
    log: ReplicationLog,
    metrics: LiveRegistry,
    opts: ClusterOptions,
}

impl Cluster {
    /// Stand up the cluster state over a static replica list. No
    /// connection is made here — routers and the replication worker
    /// connect lazily, so a replica that is still binding its listener
    /// does not fail construction.
    pub fn connect(
        endpoints: Vec<Endpoint>,
        opts: ClusterOptions,
    ) -> Cluster {
        let registry =
            Arc::new(ReplicaRegistry::new(endpoints, opts.virtual_nodes));
        let metrics = LiveRegistry::new();
        let log = ReplicationLog::new(
            Arc::clone(&registry),
            opts.request_timeout,
            &metrics,
        );
        Cluster { registry, log, metrics, opts }
    }

    /// Bind the initial vocabulary partition (see
    /// [`ReplicaRegistry::seed`]; produce it with [`shard_partition`]
    /// and build each replica's sampler over its slice **in order**).
    pub fn seed(&self, partitions: &[Vec<u32>]) {
        self.registry.seed(partitions);
    }

    /// A router handle for one client thread: owns its own per-replica
    /// serve connections, shares registry/log/metrics with every other
    /// handle.
    pub fn client(&self) -> ClusterRouter {
        ClusterRouter::new(
            Arc::clone(&self.registry),
            self.log.shared(),
            &self.metrics,
            self.opts.request_timeout,
            self.opts.hedge,
        )
    }

    pub fn registry(&self) -> &Arc<ReplicaRegistry> {
        &self.registry
    }

    /// The cluster-side telemetry registry (router counters, sub-wave
    /// latency, replication counters).
    pub fn metrics(&self) -> &LiveRegistry {
        &self.metrics
    }

    /// Await replication convergence: `true` when every queued churn
    /// entry has been applied (or abandoned on a dead replica) within
    /// the timeout.
    pub fn flush(&self, timeout: Duration) -> bool {
        self.log.flush(timeout)
    }

    /// Per-replica replication lag (queued + in-flight entries).
    pub fn lag(&self) -> Vec<u64> {
        self.log.lag()
    }

    /// Per-replica acked replication-sequence cursors.
    pub fn cursors(&self) -> Vec<u64> {
        self.log.cursors()
    }

    /// Per-replica counts of entries abandoned on dead replicas.
    pub fn dropped(&self) -> Vec<u64> {
        self.log.dropped()
    }

    /// Per-replica `(first_seq, last_seq)` abandon ranges still awaiting
    /// bootstrap replay (empty for a replica once
    /// [`Cluster::bootstrap_replica`] has re-covered them).
    pub fn abandoned(&self) -> Vec<Vec<(u64, u64)>> {
        self.log.abandoned()
    }

    /// Snapshot-bootstrap a recovered replica back into the cluster.
    ///
    /// The caller has already restarted replica `r`'s serving stack at
    /// the same endpoint from a durable snapshot (fetched earlier via
    /// the wire `STATE_SNAPSHOT` frame, or read back with
    /// [`crate::snapshot::read_file`]) whose state carries every churn
    /// op up to replication cursor `from_seq`. This verifies the parked
    /// (abandoned) log tail re-covers exactly the sequence numbers the
    /// cursor advanced past since then, re-enqueues it in FIFO order,
    /// and marks the replica healthy so the worker reconnects and
    /// drains. Returns the number of replayed ops; follow with
    /// [`Cluster::flush`] to await convergence (after which this
    /// replica's cursor has rejoined the shared sequence and
    /// [`Cluster::dropped`] for it is back to zero — no lost churn).
    pub fn bootstrap_replica(
        &self,
        r: usize,
        from_seq: u64,
    ) -> Result<u64, String> {
        let n = self.log.reenqueue_parked(r, from_seq)?;
        self.registry.replica(r).set_healthy(true);
        Ok(n)
    }

    /// Number of replicas currently marked healthy.
    pub fn alive(&self) -> usize {
        self.registry.alive().len()
    }

    /// Cluster-local state snapshot: per-replica endpoint / health /
    /// cursor / lag / last-ack epoch, plus the shared telemetry
    /// registry. This is the router's own view — per-replica *server*
    /// telemetry comes from scraping each endpoint's `STATS` frame
    /// (`rfsoftmax stats tcp:A tcp:B ...`).
    pub fn stats_json(&self) -> String {
        let lag = self.lag();
        let cursors = self.cursors();
        let dropped = self.dropped();
        let abandoned = self.abandoned();
        let epochs = self.log.epochs();
        let replicas: Vec<Json> = (0..self.registry.len())
            .map(|r| {
                let rep = self.registry.replica(r);
                Json::obj(vec![
                    ("endpoint", Json::from(rep.endpoint.to_string().as_str())),
                    ("healthy", Json::from(rep.is_healthy())),
                    ("cursor", Json::from(cursors[r] as usize)),
                    ("lag", Json::from(lag[r] as usize)),
                    ("dropped", Json::from(dropped[r] as usize)),
                    // Abandon events awaiting bootstrap replay, so a
                    // scrape distinguishes "lost for good" from
                    // "recoverable via bootstrap_replica".
                    ("abandoned_ranges", Json::from(abandoned[r].len())),
                    ("epoch", Json::from(epochs[r] as usize)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("replicas", Json::Arr(replicas)),
            ("telemetry", self.metrics.snapshot_json()),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn options_mirror_config_defaults() {
        let o = ClusterOptions::default();
        assert_eq!(o.request_timeout, Duration::from_millis(1000));
        assert!(!o.hedge);
        assert_eq!(o.virtual_nodes, 64);
    }

    #[test]
    fn replica_list_parsing() {
        let eps = parse_replicas("tcp:127.0.0.1:7001, uds:/tmp/b.sock,")
            .expect("parse");
        assert_eq!(eps.len(), 2);
        assert!(matches!(eps[0], Endpoint::Tcp(_)));
        assert_eq!(eps[1], Endpoint::Uds(PathBuf::from("/tmp/b.sock")));
        assert!(parse_replicas("").expect("empty ok").is_empty());
    }

    #[test]
    fn cluster_state_snapshot_before_any_traffic() {
        let cluster = Cluster::connect(
            vec![
                Endpoint::Uds(PathBuf::from("/tmp/rf-a.sock")),
                Endpoint::Uds(PathBuf::from("/tmp/rf-b.sock")),
            ],
            ClusterOptions::default(),
        );
        cluster.seed(&shard_partition(32, 2, 64));
        assert_eq!(cluster.alive(), 2);
        assert_eq!(cluster.lag(), vec![0, 0]);
        let stats = crate::json::parse(&cluster.stats_json()).expect("json");
        let reps = stats.get("replicas").and_then(Json::as_array).unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("healthy").and_then(Json::as_bool), Some(true));
        assert_eq!(reps[0].get("lag").and_then(Json::as_usize), Some(0));
    }
}
