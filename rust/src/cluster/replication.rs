//! Epoch-sequenced churn replication log.
//!
//! Vocabulary mutations (`ADD_CLASSES` / `RETIRE_CLASSES`) enter the
//! cluster through the router, which stamps each logical operation with
//! a monotonically increasing **sequence number** and appends one log
//! entry per *owner* replica (the consistent-hash ring decides
//! ownership of each class id, so one router-level add usually fans
//! into several per-replica entries sharing a sequence number).
//!
//! A single background worker drains the per-replica queues round-robin
//! over dedicated admin connections (separate from the router's serve
//! connections, so a slow admin apply never stalls reads). Per-replica
//! queues are strict FIFO, which is the ordering contract the id maps
//! rely on: a retire's global→local resolution happens at *apply* time,
//! after the add that created the binding has been acked on the same
//! queue.
//!
//! Progress is observable as per-replica **acked cursors** (the highest
//! applied sequence number) and **lag** (entries still queued or in
//! flight) — both surfaced through `Cluster::stats_json` and the
//! multi-endpoint `rfsoftmax stats` command. Appends return
//! immediately with the assigned ids and sequence number; callers that
//! need convergence (tests, shutdown) call
//! [`ReplicationLog::flush`].
//!
//! # Failure policy
//!
//! An apply gets one reconnect-and-retry; if the replica still will not
//! take it, the worker marks the replica unhealthy, abandons its
//! remaining queue (counting the entries as `dropped`), and advances
//! the cursor past them. This keeps `flush` from wedging on a killed
//! replica — the loss is deliberate and *visible* (dropped count +
//! health bit + failover metrics), matching the cluster's
//! degrade-loudly contract.
//!
//! Abandoned entries are not discarded: they are **parked** per replica
//! with their `(first_seq, last_seq)` ranges recorded, so
//! snapshot-bootstrap ([`LogShared::reenqueue_parked`], reached through
//! `Cluster::bootstrap_replica`) can later verify that replaying the
//! parked tail onto a restored snapshot re-covers *exactly* the
//! sequence numbers the cursor advanced past, then feed them back
//! through the same FIFO queue. That closes the old
//! abandon-with-cursor-advance durability hole: a killed replica that
//! rejoins from a snapshot converges to the shared cursor with zero
//! lost churn ops.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::registry::ReplicaRegistry;
use crate::linalg::Matrix;
use crate::metrics::live::{LiveRegistry, ShardedCounter};
use crate::transport::{ProtocolError, TransportClient};

/// One replicated vocabulary mutation, already narrowed to a single
/// owner replica's share of the logical operation. (Named apart from
/// [`crate::admin::AdminOp`], the process-local admin surface op — this
/// is the replication-log wire unit, pre-split by ring owner.)
enum ReplOp {
    /// Append these globals (row `k` of `embeddings` is `globals[k]`).
    Add { globals: Vec<u32>, embeddings: Matrix },
    /// Retire these globals (resolved to local ids at apply time).
    Retire { globals: Vec<u32> },
}

struct LogEntry {
    seq: u64,
    op: ReplOp,
}

struct LogState {
    next_seq: u64,
    queues: Vec<VecDeque<LogEntry>>,
    /// Entry popped but not yet acked, per replica — counted by `lag`
    /// and awaited by `flush`.
    inflight: Vec<bool>,
    /// Highest sequence number applied (or abandoned) per replica.
    acked: Vec<u64>,
    /// Entries currently abandoned because the replica died mid-log
    /// (decremented when bootstrap re-enqueues them).
    dropped: Vec<u64>,
    /// Abandoned entries, kept aside per replica in FIFO order for
    /// snapshot-bootstrap replay.
    parked: Vec<VecDeque<LogEntry>>,
    /// `(first_seq, last_seq)` of each abandon event, per replica — the
    /// audit record [`LogShared::reenqueue_parked`] verifies against.
    abandoned_ranges: Vec<Vec<(u64, u64)>>,
    shutdown: bool,
}

pub(crate) struct LogShared {
    registry: Arc<ReplicaRegistry>,
    state: Mutex<LogState>,
    /// Single condvar for both directions: the worker waits on it for
    /// appends, flushers wait on it for drains; every transition
    /// `notify_all`s.
    wake: Condvar,
    timeout: Duration,
    /// Last snapshot-swap epoch each replica reported in an admin ack.
    epochs: Vec<AtomicU64>,
    applied: Arc<ShardedCounter>,
    errors: Arc<ShardedCounter>,
}

impl LogShared {
    /// Append one logical add: assign fresh global ids, split the rows
    /// by ring owner, enqueue one entry per owner. Returns the global
    /// ids (row-aligned with `embeddings`) and the operation's sequence
    /// number; the binding to local ids happens asynchronously at ack.
    pub(crate) fn append_add(&self, embeddings: &Matrix) -> (Vec<u32>, u64) {
        let assigned = self.registry.assign_new(embeddings.rows());
        let globals: Vec<u32> = assigned.iter().map(|&(g, _)| g).collect();
        let n = self.registry.len();
        let mut per_replica: Vec<(Vec<u32>, Vec<f32>)> =
            vec![(Vec::new(), Vec::new()); n];
        for (row, &(g, owner)) in assigned.iter().enumerate() {
            per_replica[owner].0.push(g);
            per_replica[owner].1.extend_from_slice(embeddings.row(row));
        }
        let dim = embeddings.cols();
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        for (r, (globals, rows)) in per_replica.into_iter().enumerate() {
            if globals.is_empty() {
                continue;
            }
            let m = Matrix::from_vec(globals.len(), dim, rows);
            st.queues[r].push_back(LogEntry {
                seq,
                op: ReplOp::Add { globals, embeddings: m },
            });
        }
        drop(st);
        self.wake.notify_all();
        (globals, seq)
    }

    /// Append one logical retire, split by ring owner. Returns the
    /// sequence number.
    pub(crate) fn append_retire(&self, globals: &[u32]) -> u64 {
        let n = self.registry.len();
        let mut per_replica: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &g in globals {
            per_replica[self.registry.owner_of(g)].push(g);
        }
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        for (r, globals) in per_replica.into_iter().enumerate() {
            if globals.is_empty() {
                continue;
            }
            st.queues[r].push_back(LogEntry {
                seq,
                op: ReplOp::Retire { globals },
            });
        }
        drop(st);
        self.wake.notify_all();
        seq
    }

    /// Block until every queue is drained and no apply is in flight, or
    /// the timeout elapses. `true` means converged.
    pub(crate) fn flush(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            let busy = st.inflight.iter().any(|&b| b)
                || st.queues.iter().any(|q| !q.is_empty());
            if !busy {
                return true;
            }
            let Some(left) = deadline.checked_duration_since(Instant::now())
            else {
                return false;
            };
            st = self.wake.wait_timeout(st, left).unwrap().0;
        }
    }

    /// Per-replica replication lag: queued entries plus any in-flight
    /// apply.
    pub(crate) fn lag(&self) -> Vec<u64> {
        let st = self.state.lock().unwrap();
        st.queues
            .iter()
            .zip(&st.inflight)
            .map(|(q, &f)| q.len() as u64 + u64::from(f))
            .collect()
    }

    /// Per-replica acked sequence cursors.
    pub(crate) fn cursors(&self) -> Vec<u64> {
        self.state.lock().unwrap().acked.clone()
    }

    /// Per-replica abandoned-entry counts (dead replicas only).
    pub(crate) fn dropped(&self) -> Vec<u64> {
        self.state.lock().unwrap().dropped.clone()
    }

    /// Last admin-ack epoch per replica (0 before any ack).
    pub(crate) fn epochs(&self) -> Vec<u64> {
        self.epochs.iter().map(|e| e.load(Ordering::Relaxed)).collect()
    }

    /// Per-replica abandoned `(first_seq, last_seq)` ranges still
    /// awaiting bootstrap replay (empty once a replica has been
    /// re-bootstrapped — or was never abandoned).
    pub(crate) fn abandoned(&self) -> Vec<Vec<(u64, u64)>> {
        self.state.lock().unwrap().abandoned_ranges.clone()
    }

    /// Snapshot-bootstrap replay: feed replica `r`'s parked (abandoned)
    /// entries back through its FIFO queue, after verifying they are
    /// exactly the ops a snapshot taken at sequence cursor `from_seq`
    /// is missing.
    ///
    /// `from_seq` is the replica's acked cursor read *after a clean
    /// flush and before the crash* — i.e. the highest sequence number
    /// actually applied to the state the snapshot captured. The checks:
    ///
    /// * every parked seq must be `> from_seq` — a parked op at or
    ///   below the snapshot cursor means the snapshot is newer than the
    ///   abandon record, and replaying it would double-apply;
    /// * the parked seqs must cover the recorded abandon ranges exactly
    ///   (same multiset) — anything else means log corruption.
    ///
    /// On success the entries are re-enqueued in sequence order, the
    /// acked cursor rolls back to `from_seq` (it re-advances as the
    /// worker acks), `dropped` gives back the re-covered count, and the
    /// abandon record clears. Returns the number of re-enqueued ops.
    /// The caller marks the replica healthy and flushes.
    pub(crate) fn reenqueue_parked(
        &self,
        r: usize,
        from_seq: u64,
    ) -> Result<u64, String> {
        let mut st = self.state.lock().unwrap();
        if st.parked[r].is_empty() {
            // Nothing abandoned — nothing to replay, and the live acked
            // cursor must not be touched.
            return Ok(0);
        }
        let parked_seqs: Vec<u64> =
            st.parked[r].iter().map(|e| e.seq).collect();
        if let Some(&bad) = parked_seqs.iter().find(|&&s| s <= from_seq) {
            return Err(format!(
                "bootstrap replica {r}: parked op seq {bad} is already \
                 covered by the snapshot cursor {from_seq} — replaying it \
                 would double-apply"
            ));
        }
        let mut expected: Vec<u64> = Vec::new();
        for &(first, last) in &st.abandoned_ranges[r] {
            // Ranges are per abandon event over one replica's FIFO
            // queue; seqs within one event are strictly increasing but
            // may skip (not every seq lands on every replica), so the
            // range is an envelope — the exact seqs are the parked
            // entries inside it.
            expected.extend(
                parked_seqs.iter().filter(|&&s| s >= first && s <= last),
            );
        }
        if expected.len() != parked_seqs.len() {
            return Err(format!(
                "bootstrap replica {r}: parked ops {parked_seqs:?} do not \
                 match recorded abandon ranges {:?}",
                st.abandoned_ranges[r]
            ));
        }
        let mut replayed: VecDeque<LogEntry> =
            std::mem::take(&mut st.parked[r]);
        let n = replayed.len() as u64;
        // Parked entries kept their FIFO order; re-enqueue AHEAD of
        // anything appended since the abandon so per-replica ordering
        // (adds before the retires that resolve them) still holds.
        while let Some(e) = replayed.pop_back() {
            st.queues[r].push_front(e);
        }
        st.acked[r] = from_seq;
        st.dropped[r] = st.dropped[r].saturating_sub(n);
        st.abandoned_ranges[r].clear();
        drop(st);
        self.wake.notify_all();
        Ok(n)
    }
}

/// Handle owning the worker thread; dropping it stops the worker
/// without draining (call [`ReplicationLog::flush`] first if the queue
/// must land).
pub(crate) struct ReplicationLog {
    shared: Arc<LogShared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ReplicationLog {
    pub(crate) fn new(
        registry: Arc<ReplicaRegistry>,
        timeout: Duration,
        metrics: &LiveRegistry,
    ) -> ReplicationLog {
        let n = registry.len();
        let shared = Arc::new(LogShared {
            registry,
            state: Mutex::new(LogState {
                next_seq: 1,
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                inflight: vec![false; n],
                acked: vec![0; n],
                dropped: vec![0; n],
                parked: (0..n).map(|_| VecDeque::new()).collect(),
                abandoned_ranges: vec![Vec::new(); n],
                shutdown: false,
            }),
            wake: Condvar::new(),
            timeout,
            epochs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            applied: metrics.counter("cluster.repl_applied"),
            errors: metrics.counter("cluster.repl_errors"),
        });
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cluster-repl".into())
                .spawn(move || replication_worker(&shared))
                .expect("spawn replication worker")
        };
        ReplicationLog { shared, worker: Some(worker) }
    }

    pub(crate) fn shared(&self) -> Arc<LogShared> {
        Arc::clone(&self.shared)
    }

    pub(crate) fn flush(&self, timeout: Duration) -> bool {
        self.shared.flush(timeout)
    }

    pub(crate) fn lag(&self) -> Vec<u64> {
        self.shared.lag()
    }

    pub(crate) fn cursors(&self) -> Vec<u64> {
        self.shared.cursors()
    }

    pub(crate) fn dropped(&self) -> Vec<u64> {
        self.shared.dropped()
    }

    pub(crate) fn epochs(&self) -> Vec<u64> {
        self.shared.epochs()
    }

    pub(crate) fn abandoned(&self) -> Vec<Vec<(u64, u64)>> {
        self.shared.abandoned()
    }

    pub(crate) fn reenqueue_parked(
        &self,
        r: usize,
        from_seq: u64,
    ) -> Result<u64, String> {
        self.shared.reenqueue_parked(r, from_seq)
    }
}

impl Drop for ReplicationLog {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.wake.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The drain loop: pop round-robin, apply with one retry, ack or
/// abandon. Admin connections are lazy and owned here, one per replica.
fn replication_worker(shared: &LogShared) {
    let n = shared.registry.len();
    let mut conns: Vec<Option<TransportClient>> = (0..n).map(|_| None).collect();
    let mut cursor = 0usize;
    loop {
        // Pick the next queued entry, or sleep until one appears.
        let (r, entry) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let mut picked = None;
                for k in 0..n {
                    let r = (cursor + k) % n;
                    if let Some(entry) = st.queues[r].pop_front() {
                        picked = Some((r, entry));
                        break;
                    }
                }
                if let Some((r, entry)) = picked {
                    st.inflight[r] = true;
                    cursor = (r + 1) % n;
                    break (r, entry);
                }
                st = shared.wake.wait(st).unwrap();
            }
        };

        let result = apply_with_retry(shared, &mut conns[r], r, &entry.op);

        let mut st = shared.state.lock().unwrap();
        st.inflight[r] = false;
        match result {
            Ok(()) => {
                st.acked[r] = entry.seq;
                shared.applied.incr();
            }
            Err(_) => {
                // Replica refused twice (or its connection is gone):
                // mark it down and abandon its queue so flush cannot
                // wedge. The cursor still advances — loss is recorded
                // in `dropped`, not hidden as infinite lag — and the
                // entries are parked with their seq range recorded so
                // snapshot-bootstrap can replay exactly them later.
                shared.errors.incr();
                shared.registry.replica(r).set_healthy(false);
                conns[r] = None;
                let first = entry.seq;
                let mut last = entry.seq;
                let mut abandoned = 1u64;
                st.parked[r].push_back(entry);
                while let Some(e) = st.queues[r].pop_front() {
                    last = e.seq;
                    abandoned += 1;
                    st.parked[r].push_back(e);
                }
                st.acked[r] = last;
                st.dropped[r] += abandoned;
                st.abandoned_ranges[r].push((first, last));
            }
        }
        drop(st);
        shared.wake.notify_all();
    }
}

/// Apply one entry; a connection-closing failure gets one fresh
/// connection and a second attempt (admin frames are idempotent-enough
/// under this log: an add that *was* applied but whose ack was lost
/// would double-add, so the retry only fires when the error indicates
/// the request never reached a healthy server — connect failures and
/// timeouts close the connection before the send).
fn apply_with_retry(
    shared: &LogShared,
    conn: &mut Option<TransportClient>,
    r: usize,
    op: &ReplOp,
) -> Result<(), ProtocolError> {
    match apply_once(shared, conn, r, op) {
        Ok(()) => Ok(()),
        Err(e) if e.closes_connection() => {
            *conn = None;
            apply_once(shared, conn, r, op)
        }
        Err(e) => Err(e),
    }
}

fn apply_once(
    shared: &LogShared,
    conn: &mut Option<TransportClient>,
    r: usize,
    op: &ReplOp,
) -> Result<(), ProtocolError> {
    if conn.is_none() {
        let endpoint = &shared.registry.replica(r).endpoint;
        *conn = Some(TransportClient::connect_endpoint_timeout(
            endpoint,
            shared.timeout,
        )?);
    }
    let client = conn.as_mut().unwrap();
    match op {
        ReplOp::Add { globals, embeddings } => {
            let (locals, epoch) = client.add_classes(embeddings)?;
            if locals.len() != globals.len() {
                return Err(ProtocolError::Malformed(
                    "add ack id count mismatch",
                ));
            }
            shared.registry.bind(r, globals, &locals);
            shared.epochs[r].store(epoch, Ordering::Relaxed);
        }
        ReplOp::Retire { globals } => {
            // FIFO per replica guarantees the adds that created these
            // bindings were acked on this same queue; an unresolved id
            // here means the caller retired something never added.
            let locals: Vec<u32> = globals
                .iter()
                .filter_map(|&g| shared.registry.local_of(g))
                .collect();
            if !locals.is_empty() {
                let epoch = client.retire_classes(&locals)?;
                shared.epochs[r].store(epoch, Ordering::Relaxed);
            }
            shared.registry.unbind(globals);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::registry::shard_partition;
    use crate::transport::Endpoint;
    use std::path::PathBuf;

    fn log_over(n: usize) -> (Arc<ReplicaRegistry>, ReplicationLog, LiveRegistry) {
        let endpoints = (0..n)
            .map(|i| {
                Endpoint::Uds(PathBuf::from(format!("/tmp/rf-none-{i}.sock")))
            })
            .collect();
        let registry = Arc::new(ReplicaRegistry::new(endpoints, 32));
        let metrics = LiveRegistry::new();
        let log = ReplicationLog::new(Arc::clone(&registry), Duration::from_millis(200), &metrics);
        (registry, log, metrics)
    }

    #[test]
    fn empty_log_flushes_immediately_with_zero_lag() {
        let (_reg, log, _m) = log_over(3);
        assert!(log.flush(Duration::from_millis(50)));
        assert_eq!(log.lag(), vec![0, 0, 0]);
        assert_eq!(log.cursors(), vec![0, 0, 0]);
    }

    #[test]
    fn append_assigns_sequenced_global_ids() {
        let (reg, log, _m) = log_over(2);
        reg.seed(&shard_partition(10, 2, 32));
        let rows = Matrix::from_vec(3, 4, vec![0.5; 12]);
        let (globals, seq) = log.shared().append_add(&rows);
        assert_eq!(globals, vec![10, 11, 12]);
        assert_eq!(seq, 1);
        let seq2 = log.shared().append_retire(&globals);
        assert_eq!(seq2, 2);
        // The endpoints are dead paths, so the worker will abandon the
        // queues rather than wedge: flush must still terminate.
        assert!(log.flush(Duration::from_secs(5)), "flush may not wedge");
        assert!(log.dropped().iter().sum::<u64>() > 0);
    }

    #[test]
    fn abandoned_ops_are_parked_with_their_seq_ranges() {
        let (reg, log, _m) = log_over(2);
        reg.seed(&shard_partition(10, 2, 32));
        let rows = Matrix::from_vec(4, 3, vec![0.25; 12]);
        let (globals, seq_add) = log.shared().append_add(&rows);
        let seq_ret = log.shared().append_retire(&globals[..2]);
        assert!(log.flush(Duration::from_secs(5)), "flush may not wedge");

        // Both replicas are dead paths: everything queued was abandoned,
        // so the per-replica ranges must together envelope exactly the
        // two sequence numbers and the cursors must sit at the tail.
        let ranges = log.abandoned();
        let dropped = log.dropped();
        let total: u64 = dropped.iter().sum();
        assert!(total >= 2, "both logical ops queued somewhere");
        for (r, rs) in ranges.iter().enumerate() {
            if dropped[r] == 0 {
                assert!(rs.is_empty());
                continue;
            }
            assert!(!rs.is_empty(), "dropped implies a recorded range");
            for &(first, last) in rs {
                assert!(first >= seq_add && last <= seq_ret);
                assert!(first <= last);
            }
            assert_eq!(log.cursors()[r], rs.last().unwrap().1);
        }

        // A snapshot cursor past the parked seqs refuses the replay:
        // those ops would double-apply. (Checked before any replay —
        // the parked set is stable while the worker's queues are
        // empty.)
        let r = (0..2).find(|&r| dropped[r] > 0).unwrap();
        let err = log.reenqueue_parked(r, seq_ret).unwrap_err();
        assert!(err.contains("double-apply"), "got: {err}");

        // Replay from seq 0 (nothing applied anywhere): every parked op
        // re-enqueues. (Only the atomic return value is asserted here —
        // the worker immediately re-attempts the dead endpoints, so
        // dropped/cursors are transient until the next flush.)
        for r in 0..2 {
            let n = log.reenqueue_parked(r, 0).expect("ranges verify");
            assert_eq!(n, dropped[r], "replica {r} replays all parked ops");
        }

        // The replicas are still dead, so the replayed queue abandons
        // again rather than wedging flush — and parks again, whole.
        assert!(log.flush(Duration::from_secs(5)), "flush may not wedge");
        let again: u64 = log.dropped().iter().sum();
        assert_eq!(again, total, "replayed ops parked a second time");
    }
}
