//! Epoch-sequenced churn replication log.
//!
//! Vocabulary mutations (`ADD_CLASSES` / `RETIRE_CLASSES`) enter the
//! cluster through the router, which stamps each logical operation with
//! a monotonically increasing **sequence number** and appends one log
//! entry per *owner* replica (the consistent-hash ring decides
//! ownership of each class id, so one router-level add usually fans
//! into several per-replica entries sharing a sequence number).
//!
//! A single background worker drains the per-replica queues round-robin
//! over dedicated admin connections (separate from the router's serve
//! connections, so a slow admin apply never stalls reads). Per-replica
//! queues are strict FIFO, which is the ordering contract the id maps
//! rely on: a retire's global→local resolution happens at *apply* time,
//! after the add that created the binding has been acked on the same
//! queue.
//!
//! Progress is observable as per-replica **acked cursors** (the highest
//! applied sequence number) and **lag** (entries still queued or in
//! flight) — both surfaced through `Cluster::stats_json` and the
//! multi-endpoint `rfsoftmax stats` command. Appends return
//! immediately with the assigned ids and sequence number; callers that
//! need convergence (tests, shutdown) call
//! [`ReplicationLog::flush`].
//!
//! # Failure policy
//!
//! An apply gets one reconnect-and-retry; if the replica still will not
//! take it, the worker marks the replica unhealthy, abandons its
//! remaining queue (counting the entries as `dropped`), and advances
//! the cursor past them. This keeps `flush` from wedging on a killed
//! replica — the loss is deliberate and *visible* (dropped count +
//! health bit + failover metrics), matching the cluster's
//! degrade-loudly contract.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::registry::ReplicaRegistry;
use crate::linalg::Matrix;
use crate::metrics::live::{LiveRegistry, ShardedCounter};
use crate::transport::{ProtocolError, TransportClient};

/// One replicated vocabulary mutation, already narrowed to a single
/// owner replica's share of the logical operation.
enum AdminOp {
    /// Append these globals (row `k` of `embeddings` is `globals[k]`).
    Add { globals: Vec<u32>, embeddings: Matrix },
    /// Retire these globals (resolved to local ids at apply time).
    Retire { globals: Vec<u32> },
}

struct LogEntry {
    seq: u64,
    op: AdminOp,
}

struct LogState {
    next_seq: u64,
    queues: Vec<VecDeque<LogEntry>>,
    /// Entry popped but not yet acked, per replica — counted by `lag`
    /// and awaited by `flush`.
    inflight: Vec<bool>,
    /// Highest sequence number applied (or abandoned) per replica.
    acked: Vec<u64>,
    /// Entries abandoned because the replica died mid-log.
    dropped: Vec<u64>,
    shutdown: bool,
}

pub(crate) struct LogShared {
    registry: Arc<ReplicaRegistry>,
    state: Mutex<LogState>,
    /// Single condvar for both directions: the worker waits on it for
    /// appends, flushers wait on it for drains; every transition
    /// `notify_all`s.
    wake: Condvar,
    timeout: Duration,
    /// Last snapshot-swap epoch each replica reported in an admin ack.
    epochs: Vec<AtomicU64>,
    applied: Arc<ShardedCounter>,
    errors: Arc<ShardedCounter>,
}

impl LogShared {
    /// Append one logical add: assign fresh global ids, split the rows
    /// by ring owner, enqueue one entry per owner. Returns the global
    /// ids (row-aligned with `embeddings`) and the operation's sequence
    /// number; the binding to local ids happens asynchronously at ack.
    pub(crate) fn append_add(&self, embeddings: &Matrix) -> (Vec<u32>, u64) {
        let assigned = self.registry.assign_new(embeddings.rows());
        let globals: Vec<u32> = assigned.iter().map(|&(g, _)| g).collect();
        let n = self.registry.len();
        let mut per_replica: Vec<(Vec<u32>, Vec<f32>)> =
            vec![(Vec::new(), Vec::new()); n];
        for (row, &(g, owner)) in assigned.iter().enumerate() {
            per_replica[owner].0.push(g);
            per_replica[owner].1.extend_from_slice(embeddings.row(row));
        }
        let dim = embeddings.cols();
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        for (r, (globals, rows)) in per_replica.into_iter().enumerate() {
            if globals.is_empty() {
                continue;
            }
            let m = Matrix::from_vec(globals.len(), dim, rows);
            st.queues[r].push_back(LogEntry {
                seq,
                op: AdminOp::Add { globals, embeddings: m },
            });
        }
        drop(st);
        self.wake.notify_all();
        (globals, seq)
    }

    /// Append one logical retire, split by ring owner. Returns the
    /// sequence number.
    pub(crate) fn append_retire(&self, globals: &[u32]) -> u64 {
        let n = self.registry.len();
        let mut per_replica: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &g in globals {
            per_replica[self.registry.owner_of(g)].push(g);
        }
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        for (r, globals) in per_replica.into_iter().enumerate() {
            if globals.is_empty() {
                continue;
            }
            st.queues[r].push_back(LogEntry {
                seq,
                op: AdminOp::Retire { globals },
            });
        }
        drop(st);
        self.wake.notify_all();
        seq
    }

    /// Block until every queue is drained and no apply is in flight, or
    /// the timeout elapses. `true` means converged.
    pub(crate) fn flush(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            let busy = st.inflight.iter().any(|&b| b)
                || st.queues.iter().any(|q| !q.is_empty());
            if !busy {
                return true;
            }
            let Some(left) = deadline.checked_duration_since(Instant::now())
            else {
                return false;
            };
            st = self.wake.wait_timeout(st, left).unwrap().0;
        }
    }

    /// Per-replica replication lag: queued entries plus any in-flight
    /// apply.
    pub(crate) fn lag(&self) -> Vec<u64> {
        let st = self.state.lock().unwrap();
        st.queues
            .iter()
            .zip(&st.inflight)
            .map(|(q, &f)| q.len() as u64 + u64::from(f))
            .collect()
    }

    /// Per-replica acked sequence cursors.
    pub(crate) fn cursors(&self) -> Vec<u64> {
        self.state.lock().unwrap().acked.clone()
    }

    /// Per-replica abandoned-entry counts (dead replicas only).
    pub(crate) fn dropped(&self) -> Vec<u64> {
        self.state.lock().unwrap().dropped.clone()
    }

    /// Last admin-ack epoch per replica (0 before any ack).
    pub(crate) fn epochs(&self) -> Vec<u64> {
        self.epochs.iter().map(|e| e.load(Ordering::Relaxed)).collect()
    }
}

/// Handle owning the worker thread; dropping it stops the worker
/// without draining (call [`ReplicationLog::flush`] first if the queue
/// must land).
pub(crate) struct ReplicationLog {
    shared: Arc<LogShared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ReplicationLog {
    pub(crate) fn new(
        registry: Arc<ReplicaRegistry>,
        timeout: Duration,
        metrics: &LiveRegistry,
    ) -> ReplicationLog {
        let n = registry.len();
        let shared = Arc::new(LogShared {
            registry,
            state: Mutex::new(LogState {
                next_seq: 1,
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                inflight: vec![false; n],
                acked: vec![0; n],
                dropped: vec![0; n],
                shutdown: false,
            }),
            wake: Condvar::new(),
            timeout,
            epochs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            applied: metrics.counter("cluster.repl_applied"),
            errors: metrics.counter("cluster.repl_errors"),
        });
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cluster-repl".into())
                .spawn(move || replication_worker(&shared))
                .expect("spawn replication worker")
        };
        ReplicationLog { shared, worker: Some(worker) }
    }

    pub(crate) fn shared(&self) -> Arc<LogShared> {
        Arc::clone(&self.shared)
    }

    pub(crate) fn flush(&self, timeout: Duration) -> bool {
        self.shared.flush(timeout)
    }

    pub(crate) fn lag(&self) -> Vec<u64> {
        self.shared.lag()
    }

    pub(crate) fn cursors(&self) -> Vec<u64> {
        self.shared.cursors()
    }

    pub(crate) fn dropped(&self) -> Vec<u64> {
        self.shared.dropped()
    }

    pub(crate) fn epochs(&self) -> Vec<u64> {
        self.shared.epochs()
    }
}

impl Drop for ReplicationLog {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.wake.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The drain loop: pop round-robin, apply with one retry, ack or
/// abandon. Admin connections are lazy and owned here, one per replica.
fn replication_worker(shared: &LogShared) {
    let n = shared.registry.len();
    let mut conns: Vec<Option<TransportClient>> = (0..n).map(|_| None).collect();
    let mut cursor = 0usize;
    loop {
        // Pick the next queued entry, or sleep until one appears.
        let (r, entry) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let mut picked = None;
                for k in 0..n {
                    let r = (cursor + k) % n;
                    if let Some(entry) = st.queues[r].pop_front() {
                        picked = Some((r, entry));
                        break;
                    }
                }
                if let Some((r, entry)) = picked {
                    st.inflight[r] = true;
                    cursor = (r + 1) % n;
                    break (r, entry);
                }
                st = shared.wake.wait(st).unwrap();
            }
        };

        let result = apply_with_retry(shared, &mut conns[r], r, &entry.op);

        let mut st = shared.state.lock().unwrap();
        st.inflight[r] = false;
        match result {
            Ok(()) => {
                st.acked[r] = entry.seq;
                shared.applied.incr();
            }
            Err(_) => {
                // Replica refused twice (or its connection is gone):
                // mark it down and abandon its queue so flush cannot
                // wedge. The cursor still advances — loss is recorded
                // in `dropped`, not hidden as infinite lag.
                shared.errors.incr();
                shared.registry.replica(r).set_healthy(false);
                conns[r] = None;
                let mut last = entry.seq;
                let mut abandoned = 1u64;
                while let Some(e) = st.queues[r].pop_front() {
                    last = e.seq;
                    abandoned += 1;
                }
                st.acked[r] = last;
                st.dropped[r] += abandoned;
            }
        }
        drop(st);
        shared.wake.notify_all();
    }
}

/// Apply one entry; a connection-closing failure gets one fresh
/// connection and a second attempt (admin frames are idempotent-enough
/// under this log: an add that *was* applied but whose ack was lost
/// would double-add, so the retry only fires when the error indicates
/// the request never reached a healthy server — connect failures and
/// timeouts close the connection before the send).
fn apply_with_retry(
    shared: &LogShared,
    conn: &mut Option<TransportClient>,
    r: usize,
    op: &AdminOp,
) -> Result<(), ProtocolError> {
    match apply_once(shared, conn, r, op) {
        Ok(()) => Ok(()),
        Err(e) if e.closes_connection() => {
            *conn = None;
            apply_once(shared, conn, r, op)
        }
        Err(e) => Err(e),
    }
}

fn apply_once(
    shared: &LogShared,
    conn: &mut Option<TransportClient>,
    r: usize,
    op: &AdminOp,
) -> Result<(), ProtocolError> {
    if conn.is_none() {
        let endpoint = &shared.registry.replica(r).endpoint;
        *conn = Some(TransportClient::connect_endpoint_timeout(
            endpoint,
            shared.timeout,
        )?);
    }
    let client = conn.as_mut().unwrap();
    match op {
        AdminOp::Add { globals, embeddings } => {
            let (locals, epoch) = client.add_classes(embeddings)?;
            if locals.len() != globals.len() {
                return Err(ProtocolError::Malformed(
                    "add ack id count mismatch",
                ));
            }
            shared.registry.bind(r, globals, &locals);
            shared.epochs[r].store(epoch, Ordering::Relaxed);
        }
        AdminOp::Retire { globals } => {
            // FIFO per replica guarantees the adds that created these
            // bindings were acked on this same queue; an unresolved id
            // here means the caller retired something never added.
            let locals: Vec<u32> = globals
                .iter()
                .filter_map(|&g| shared.registry.local_of(g))
                .collect();
            if !locals.is_empty() {
                let epoch = client.retire_classes(&locals)?;
                shared.epochs[r].store(epoch, Ordering::Relaxed);
            }
            shared.registry.unbind(globals);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::registry::shard_partition;
    use crate::transport::Endpoint;
    use std::path::PathBuf;

    fn log_over(n: usize) -> (Arc<ReplicaRegistry>, ReplicationLog, LiveRegistry) {
        let endpoints = (0..n)
            .map(|i| {
                Endpoint::Uds(PathBuf::from(format!("/tmp/rf-none-{i}.sock")))
            })
            .collect();
        let registry = Arc::new(ReplicaRegistry::new(endpoints, 32));
        let metrics = LiveRegistry::new();
        let log = ReplicationLog::new(Arc::clone(&registry), Duration::from_millis(200), &metrics);
        (registry, log, metrics)
    }

    #[test]
    fn empty_log_flushes_immediately_with_zero_lag() {
        let (_reg, log, _m) = log_over(3);
        assert!(log.flush(Duration::from_millis(50)));
        assert_eq!(log.lag(), vec![0, 0, 0]);
        assert_eq!(log.cursors(), vec![0, 0, 0]);
    }

    #[test]
    fn append_assigns_sequenced_global_ids() {
        let (reg, log, _m) = log_over(2);
        reg.seed(&shard_partition(10, 2, 32));
        let rows = Matrix::from_vec(3, 4, vec![0.5; 12]);
        let (globals, seq) = log.shared().append_add(&rows);
        assert_eq!(globals, vec![10, 11, 12]);
        assert_eq!(seq, 1);
        let seq2 = log.shared().append_retire(&globals);
        assert_eq!(seq2, 2);
        // The endpoints are dead paths, so the worker will abandon the
        // queues rather than wedge: flush must still terminate.
        assert!(log.flush(Duration::from_secs(5)), "flush may not wedge");
        assert!(log.dropped().iter().sum::<u64>() > 0);
    }
}
