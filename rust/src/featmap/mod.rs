//! Kernel feature maps — the heart of RF-softmax (paper §3).
//!
//! A [`FeatureMap`] is a nonlinear map `φ: ℝᵈ → ℝᴰ` linearizing a kernel:
//! `K(h, c) ≈ φ(h)ᵀ φ(c)`. Kernel-based sampling (paper §3.1) then draws
//! class `i` with probability `q_i ∝ φ(c_i)ᵀ φ(h)` in `O(D log n)` via the
//! [`crate::sampler::KernelTree`].
//!
//! Implemented maps:
//!
//! * [`RffMap`] — classic Random Fourier Features for the Gaussian kernel
//!   (paper eq. 17): `φ(u) = √(1/D) [cos(Wu) ‖ sin(Wu)]`, `W ~ N(0, I/ν)`
//!   — 2D output coordinates for D frequencies. For L2-normalized inputs,
//!   `e^{ν uᵀv} = e^{ν} e^{-ν‖u−v‖²/2}` (paper eq. 16), so RFF approximates
//!   the exponential (softmax) kernel up to the constant `e^{ν}` which
//!   cancels under normalization of q.
//! * [`OrfMap`] — Orthogonal Random Features (Yu et al. 2016): rows of W
//!   orthogonalized, same estimator with strictly lower variance.
//! * [`SorfMap`] — Structured ORF: `W ≈ √(ν⁻¹)·(d^{-1/2} H D₁ H D₂ H D₃)`
//!   blocks where H is Walsh–Hadamard and Dᵢ are random sign diagonals;
//!   `φ` costs `O(D log d)` via the fast Walsh–Hadamard transform.
//! * [`MaclaurinMap`] — Random Maclaurin features for the *exponential*
//!   (dot-product) kernel (Kar & Karnick 2012): unbiased but high-variance;
//!   reproduced as the Table-1 baseline.
//! * [`QuadraticMap`] — explicit linearization `φ(z) = [√α·(z⊗z), 1]` of
//!   the quadratic kernel `α(hᵀc)² + 1` (Blanc & Rendle 2018), the paper's
//!   main kernel-sampling baseline. `D = d² + 1`.

mod maclaurin;
mod quadratic;
mod rff;
mod sorf;

pub use maclaurin::MaclaurinMap;
pub use quadratic::QuadraticMap;
pub use rff::{OrfMap, RffMap};
pub use sorf::{fwht, SorfMap};

use crate::linalg::{dot, Matrix};

/// A feature map linearizing a kernel: `K(x, y) ≈ φ(x)ᵀφ(y)`.
pub trait FeatureMap: Send + Sync {
    /// Output dimensionality D′ of φ (for RFF this is 2·D frequencies).
    fn output_dim(&self) -> usize;

    /// Input dimensionality d.
    fn input_dim(&self) -> usize;

    /// Compute φ(u) into `out` (`out.len() == output_dim()`).
    fn map_into(&self, u: &[f32], out: &mut [f32]);

    /// Allocating convenience wrapper.
    fn map(&self, u: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.map_into(u, &mut out);
        out
    }

    /// Batch-map: row `i` of `out` becomes `φ(u.row(i))`.
    ///
    /// Default implementation loops [`FeatureMap::map_into`] per row;
    /// projection-based maps override with one blocked gemm
    /// (`U · Wᵀ` via [`Matrix::matmul_nt`]) followed by the pointwise
    /// nonlinearity — the batch-first entry point of the sampling
    /// pipeline.
    fn map_batch_into(&self, u: &Matrix, out: &mut Matrix) {
        assert_eq!(u.cols(), self.input_dim(), "map_batch_into: input dim");
        assert_eq!(out.cols(), self.output_dim(), "map_batch_into: output dim");
        assert_eq!(u.rows(), out.rows(), "map_batch_into: batch mismatch");
        for i in 0..u.rows() {
            self.map_into(u.row(i), out.row_mut(i));
        }
    }

    /// Allocating batch-map convenience wrapper.
    fn map_batch(&self, u: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(u.rows(), self.output_dim());
        self.map_batch_into(u, &mut out);
        out
    }

    /// The kernel value this map approximates, evaluated *exactly*
    /// (used by tests and the Table-1 MSE harness).
    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64;

    /// The approximate kernel `φ(x)ᵀφ(y)`.
    fn approx_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        dot(&self.map(x), &self.map(y)) as f64
    }
}

/// Exact exponential (softmax) kernel `exp(τ·xᵀy)`.
pub fn exp_kernel(tau: f32, x: &[f32], y: &[f32]) -> f64 {
    ((tau * dot(x, y)) as f64).exp()
}

/// Exact Gaussian kernel `exp(-ν‖x−y‖²/2)`.
pub fn gaussian_kernel(nu: f32, x: &[f32], y: &[f32]) -> f64 {
    let mut d2 = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        let diff = (a - b) as f64;
        d2 += diff * diff;
    }
    (-(nu as f64) * d2 / 2.0).exp()
}

/// Mean squared error of `map`'s kernel approximation over sample pairs.
/// This is exactly the quantity of paper Table 1.
pub fn kernel_mse(
    map: &dyn FeatureMap,
    pairs: &[(Vec<f32>, Vec<f32>)],
) -> f64 {
    let mut se = 0.0;
    for (x, y) in pairs {
        let exact = map.exact_kernel(x, y);
        let approx = map.approx_kernel(x, y);
        se += (exact - approx) * (exact - approx);
    }
    se / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;
    use crate::rng::Rng;

    /// Every map (default impl and overrides alike) must satisfy:
    /// `map_batch(U).row(i) == map(U.row(i))`.
    #[test]
    fn batch_map_matches_per_row_for_all_maps() {
        let mut rng = Rng::seeded(43);
        let d = 12;
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(RffMap::new(d, 32, 2.0, &mut rng)),
            Box::new(OrfMap::new(d, 32, 2.0, &mut rng)),
            Box::new(SorfMap::new(d, 32, 2.0, &mut rng)),
            Box::new(QuadraticMap::new(d, 100.0, 1.0)),
            Box::new(MaclaurinMap::new(d, 32, 1.0, &mut rng)),
        ];
        let mut u = Matrix::zeros(5, d);
        for i in 0..5 {
            let v = unit_vector(&mut rng, d);
            u.row_mut(i).copy_from_slice(&v);
        }
        for map in &maps {
            let batch = map.map_batch(&u);
            assert_eq!(batch.rows(), 5);
            assert_eq!(batch.cols(), map.output_dim());
            for i in 0..5 {
                let single = map.map(u.row(i));
                for (a, b) in batch.row(i).iter().zip(&single) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "row {i}: batch {a} vs scalar {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn exp_and_gaussian_kernels_agree_on_sphere() {
        // For unit vectors: exp(ν xᵀy) = e^ν · exp(-ν‖x−y‖²/2)  (eq. 16).
        let mut rng = Rng::seeded(41);
        let nu = 3.0f32;
        for _ in 0..20 {
            let x = unit_vector(&mut rng, 16);
            let y = unit_vector(&mut rng, 16);
            let lhs = exp_kernel(nu, &x, &y);
            let rhs = (nu as f64).exp() * gaussian_kernel(nu, &x, &y);
            assert!(
                (lhs - rhs).abs() < 1e-6 * lhs.abs().max(1.0),
                "{lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn kernel_mse_zero_for_perfect_map() {
        // A trivial identity-ish map whose exact kernel is defined as its
        // own approximation must give MSE 0.
        struct Identity;
        impl FeatureMap for Identity {
            fn output_dim(&self) -> usize {
                4
            }
            fn input_dim(&self) -> usize {
                4
            }
            fn map_into(&self, u: &[f32], out: &mut [f32]) {
                out.copy_from_slice(u);
            }
            fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
                dot(x, y) as f64
            }
        }
        let mut rng = Rng::seeded(42);
        let pairs: Vec<_> = (0..10)
            .map(|_| (unit_vector(&mut rng, 4), unit_vector(&mut rng, 4)))
            .collect();
        assert!(kernel_mse(&Identity, &pairs) < 1e-10);
    }
}
