//! Random Maclaurin features (Kar & Karnick, AISTATS 2012) for the
//! exponential dot-product kernel `K(x,y) = exp(τ·xᵀy)` — the Table-1
//! baseline the paper shows to be a *poor* choice (rank-deficient features
//! ⇒ large D needed for small MSE).
//!
//! Construction per output coordinate `j`:
//!   1. draw a Maclaurin order `k_j` with `P(k) = 2^{-(k+1)}`,
//!   2. draw `k_j` Rademacher vectors `w₁..w_k ∈ {±1}ᵈ`,
//!   3. `φ_j(x) = √(a_k / (D·p_k)) · Π_l (w_lᵀ x)`,
//! with `a_k = τᵏ/k!` the Maclaurin coefficient of `exp(τ·)`.
//! Then `E[φ(x)ᵀφ(y)] = Σ_k a_k (xᵀy)^k = exp(τ·xᵀy)` exactly.

use super::FeatureMap;
use crate::rng::Rng;

#[derive(Clone, Debug)]
struct Feature {
    /// Coefficient √(a_k/(D·p_k)).
    scale: f32,
    /// Rademacher signs, k vectors of length d, stored flat.
    signs: Vec<f32>,
    order: usize,
}

/// Random Maclaurin map for `exp(τ·xᵀy)`.
#[derive(Clone, Debug)]
pub struct MaclaurinMap {
    features: Vec<Feature>,
    input_dim: usize,
    tau: f32,
    max_order: usize,
}

impl MaclaurinMap {
    /// `dim` = D output coordinates. Orders are truncated at `max_order`
    /// (tail mass renormalized into p_k); 16 covers exp to f32 precision
    /// for |τ·xᵀy| ≤ ~8.
    pub fn new(input_dim: usize, dim: usize, tau: f32, rng: &mut Rng) -> Self {
        Self::with_max_order(input_dim, dim, tau, 16, rng)
    }

    pub fn with_max_order(
        input_dim: usize,
        dim: usize,
        tau: f32,
        max_order: usize,
        rng: &mut Rng,
    ) -> Self {
        assert!(input_dim > 0 && dim > 0);
        // p_k ∝ 2^{-(k+1)}, truncated and renormalized.
        let raw: Vec<f64> = (0..=max_order).map(|k| 0.5f64.powi(k as i32 + 1)).collect();
        let z: f64 = raw.iter().sum();
        let pk: Vec<f64> = raw.iter().map(|p| p / z).collect();
        // a_k = τ^k / k!.
        let mut ak = vec![1.0f64];
        for k in 1..=max_order {
            ak.push(ak[k - 1] * tau as f64 / k as f64);
        }
        let features = (0..dim)
            .map(|_| {
                let order = {
                    let u = rng.f64();
                    let mut acc = 0.0;
                    let mut ord = max_order;
                    for (k, &p) in pk.iter().enumerate() {
                        acc += p;
                        if u < acc {
                            ord = k;
                            break;
                        }
                    }
                    ord
                };
                let scale =
                    ((ak[order] / (dim as f64 * pk[order])).sqrt()) as f32;
                let signs: Vec<f32> = (0..order * input_dim)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                Feature { scale, signs, order }
            })
            .collect();
        Self { features, input_dim, tau, max_order }
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }

    pub fn max_order(&self) -> usize {
        self.max_order
    }
}

impl FeatureMap for MaclaurinMap {
    fn output_dim(&self) -> usize {
        self.features.len()
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn map_into(&self, u: &[f32], out: &mut [f32]) {
        debug_assert_eq!(u.len(), self.input_dim);
        debug_assert_eq!(out.len(), self.features.len());
        let d = self.input_dim;
        for (o, f) in out.iter_mut().zip(&self.features) {
            let mut prod = f.scale;
            for l in 0..f.order {
                let w = &f.signs[l * d..(l + 1) * d];
                prod *= crate::linalg::dot(w, u);
            }
            *o = prod;
        }
    }

    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        super::exp_kernel(self.tau, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::kernel_mse;
    use crate::linalg::unit_vector;

    #[test]
    fn unbiased_for_exp_kernel() {
        let mut rng = Rng::seeded(81);
        let d = 8;
        let tau = 1.0;
        let x = unit_vector(&mut rng, d);
        let y = unit_vector(&mut rng, d);
        let exact = crate::featmap::exp_kernel(tau, &x, &y);
        let mut acc = 0.0;
        let reps = 400;
        for _ in 0..reps {
            let m = MaclaurinMap::new(d, 128, tau, &mut rng);
            acc += m.approx_kernel(&x, &y);
        }
        let est = acc / reps as f64;
        assert!(
            (est - exact).abs() < 0.1,
            "bias too large: {est} vs {exact}"
        );
    }

    #[test]
    fn higher_variance_than_rff_at_same_d() {
        // The Table-1 phenomenon: Maclaurin ≫ RFF in MSE at the same D.
        use crate::featmap::{exp_kernel, FeatureMap, RffMap};
        let mut rng = Rng::seeded(82);
        let d = 16;
        let tau = 1.0;
        let pairs: Vec<_> = (0..200)
            .map(|_| (unit_vector(&mut rng, d), unit_vector(&mut rng, d)))
            .collect();
        // Compare against the exp-kernel target for both maps.
        let reps = 4;
        let mut mac_mse = 0.0;
        let mut rff_mse = 0.0;
        for _ in 0..reps {
            let mac = MaclaurinMap::new(d, 256, tau, &mut rng);
            mac_mse += kernel_mse(&mac, &pairs);
            let rff = RffMap::new(d, 128, tau, &mut rng); // output dim 256
            // RFF estimates the Gaussian kernel; for normalized data the
            // exp-kernel estimate is e^ν·φᵀφ.
            let scale = (tau as f64).exp();
            rff_mse += pairs
                .iter()
                .map(|(x, y)| {
                    let e = exp_kernel(tau, x, y) - scale * rff.approx_kernel(x, y);
                    e * e
                })
                .sum::<f64>()
                / pairs.len() as f64;
        }
        // The gap widens dramatically with D (paper Table 1 uses D = 256²);
        // at this small D we only require a clear ordering.
        assert!(
            mac_mse > 1.2 * rff_mse,
            "maclaurin {mac_mse:.3e} should exceed rff {rff_mse:.3e}"
        );
    }

    #[test]
    fn orders_distributed_geometrically() {
        let mut rng = Rng::seeded(83);
        let m = MaclaurinMap::new(4, 4096, 1.0, &mut rng);
        let zero_order = m.features.iter().filter(|f| f.order == 0).count();
        let frac = zero_order as f64 / 4096.0;
        assert!((frac - 0.5).abs() < 0.05, "P(k=0) ≈ 0.5, got {frac}");
    }
}
