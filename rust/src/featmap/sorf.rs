//! Structured Orthogonal Random Features (SORF, Yu et al. 2016).
//!
//! Replaces the dense Gaussian frequency matrix by products of Walsh–
//! Hadamard transforms and random sign diagonals:
//!
//! `W_SORF = √(d)·ν^{1/2} · H̃D₁H̃D₂H̃D₃`
//!
//! where `H̃ = H/√d` is the normalized Hadamard matrix and `Dᵢ` are random
//! ±1 diagonals. Computing `Wu` costs `O(D log d)` via the fast Walsh–
//! Hadamard transform ([`fwht`]) instead of `O(Dd)` — this is the paper's
//! §3.2 remark that SORF reduces the map cost to `O(D log d)`.
//!
//! Input dims are zero-padded to the next power of two (zero padding
//! preserves pairwise distances, hence the Gaussian kernel).

use super::FeatureMap;
use crate::rng::Rng;

/// In-place fast Walsh–Hadamard transform (unnormalized): applies the
/// ±1 Hadamard matrix H. `data.len()` must be a power of two.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    debug_assert!(n.is_power_of_two(), "fwht: length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let x = data[j];
                let y = data[j + h];
                data[j] = x + y;
                data[j + h] = x - y;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// One HD₁HD₂HD₃ block operating on the padded dimension.
#[derive(Clone, Debug)]
struct SorfBlock {
    /// Sign diagonals, applied right-to-left: d3 first.
    d1: Vec<f32>,
    d2: Vec<f32>,
    d3: Vec<f32>,
}

impl SorfBlock {
    fn new(dim: usize, rng: &mut Rng) -> Self {
        let signs = |rng: &mut Rng| -> Vec<f32> {
            (0..dim)
                .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect()
        };
        Self { d1: signs(rng), d2: signs(rng), d3: signs(rng) }
    }

    /// scratch := block(u_padded); scratch.len() == padded dim.
    fn apply(&self, scratch: &mut [f32]) {
        let n = scratch.len() as f32;
        let inv_sqrt_n = 1.0 / n.sqrt();
        for (v, s) in scratch.iter_mut().zip(&self.d3) {
            *v *= s;
        }
        fwht(scratch);
        for (v, s) in scratch.iter_mut().zip(&self.d2) {
            *v *= s * inv_sqrt_n;
        }
        fwht(scratch);
        for (v, s) in scratch.iter_mut().zip(&self.d1) {
            *v *= s * inv_sqrt_n;
        }
        fwht(scratch);
        // Final H̃ normalization folded with the global √d scale below.
        for v in scratch.iter_mut() {
            *v *= inv_sqrt_n;
        }
    }
}

/// SORF feature map for the Gaussian kernel with parameter ν.
#[derive(Clone, Debug)]
pub struct SorfMap {
    blocks: Vec<SorfBlock>,
    input_dim: usize,
    padded: usize,
    num_freqs: usize,
    nu: f32,
    inv_sqrt_d: f32,
}

impl SorfMap {
    /// `num_freqs` = D frequencies (output dim 2D). D is rounded up
    /// internally to a multiple of the padded input dim; excess rows of the
    /// last block are simply unused.
    pub fn new(input_dim: usize, num_freqs: usize, nu: f32, rng: &mut Rng) -> Self {
        assert!(input_dim > 0 && num_freqs > 0);
        assert!(nu > 0.0, "SorfMap: ν must be positive");
        let padded = input_dim.next_power_of_two();
        let nblocks = num_freqs.div_ceil(padded);
        let blocks = (0..nblocks).map(|_| SorfBlock::new(padded, rng)).collect();
        Self {
            blocks,
            input_dim,
            padded,
            num_freqs,
            nu,
            inv_sqrt_d: 1.0 / (num_freqs as f32).sqrt(),
        }
    }

    pub fn nu(&self) -> f32 {
        self.nu
    }

    pub fn num_freqs(&self) -> usize {
        self.num_freqs
    }

    /// Core φ computation with caller-provided FWHT scratch
    /// (`scratch.len() == self.padded`), shared by the scalar and batch
    /// entry points.
    fn map_into_with_scratch(&self, u: &[f32], out: &mut [f32], scratch: &mut [f32]) {
        debug_assert_eq!(u.len(), self.input_dim);
        debug_assert_eq!(out.len(), 2 * self.num_freqs);
        debug_assert_eq!(scratch.len(), self.padded);
        // Row norms of W_SORF are exactly √(padded); scaling by
        // √ν·√padded makes wᵀu match the N(0, νI) projection scale.
        let scale = (self.nu * self.padded as f32).sqrt();
        let mut emitted = 0usize;
        for block in &self.blocks {
            scratch[..self.input_dim].copy_from_slice(u);
            scratch[self.input_dim..].fill(0.0);
            block.apply(scratch);
            let take = (self.num_freqs - emitted).min(self.padded);
            for j in 0..take {
                let proj = scratch[j] * scale;
                let (s, c) = proj.sin_cos();
                out[emitted + j] = c * self.inv_sqrt_d;
                out[self.num_freqs + emitted + j] = s * self.inv_sqrt_d;
            }
            emitted += take;
        }
    }
}

impl FeatureMap for SorfMap {
    fn output_dim(&self) -> usize {
        2 * self.num_freqs
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn map_into(&self, u: &[f32], out: &mut [f32]) {
        let mut scratch = vec![0.0f32; self.padded];
        self.map_into_with_scratch(u, out, &mut scratch);
    }

    /// Batch override: one FWHT scratch buffer serves every row (the
    /// transform itself is already `O(D log d)`; the per-call allocation
    /// was the batch-path overhead).
    fn map_batch_into(&self, u: &crate::linalg::Matrix, out: &mut crate::linalg::Matrix) {
        assert_eq!(u.cols(), self.input_dim, "map_batch_into: input dim");
        assert_eq!(out.cols(), 2 * self.num_freqs, "map_batch_into: output dim");
        assert_eq!(u.rows(), out.rows(), "map_batch_into: batch mismatch");
        let mut scratch = vec![0.0f32; self.padded];
        for i in 0..u.rows() {
            self.map_into_with_scratch(u.row(i), out.row_mut(i), &mut scratch);
        }
    }

    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        super::gaussian_kernel(self.nu, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::{gaussian_kernel, kernel_mse, RffMap};
    use crate::linalg::unit_vector;

    #[test]
    fn fwht_matches_naive_hadamard() {
        // H_2 ⊗ H_2: verify against a hand-computed 4-point transform.
        let mut v = vec![1.0f32, 2.0, 3.0, 4.0];
        fwht(&mut v);
        assert_eq!(v, vec![10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_is_scaled_involution() {
        // H·H = n·I.
        let mut rng = Rng::seeded(61);
        let n = 64;
        let orig: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut v = orig.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(orig.iter()) {
            assert!((a / n as f32 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sorf_output_norm_is_one() {
        let mut rng = Rng::seeded(62);
        let m = SorfMap::new(10, 48, 2.0, &mut rng);
        let u = unit_vector(&mut rng, 10);
        let phi = m.map(&u);
        assert_eq!(phi.len(), 96);
        let norm2: f32 = phi.iter().map(|v| v * v).sum();
        assert!((norm2 - 1.0).abs() < 1e-4, "‖φ‖² = {norm2}");
    }

    #[test]
    fn sorf_approximates_gaussian_kernel() {
        let mut rng = Rng::seeded(63);
        let d = 32;
        let nu = 1.0;
        let ps: Vec<_> = (0..200)
            .map(|_| (unit_vector(&mut rng, d), unit_vector(&mut rng, d)))
            .collect();
        // Average MSE over independent maps (single-map MSE fluctuates).
        let mut mse = 0.0;
        let reps = 6;
        for _ in 0..reps {
            let m = SorfMap::new(d, 256, nu, &mut rng);
            mse += kernel_mse(&m, &ps);
        }
        mse /= reps as f64;
        // Must be comparable to plain RFF at the same D.
        let mut rff = 0.0;
        for _ in 0..reps {
            let m = RffMap::new(d, 256, nu, &mut rng);
            rff += kernel_mse(&m, &ps);
        }
        rff /= reps as f64;
        assert!(
            mse < rff * 1.5 + 1e-4,
            "sorf mse {mse:.3e} vs rff {rff:.3e}"
        );
    }

    #[test]
    fn sorf_low_bias_pointwise() {
        let mut rng = Rng::seeded(64);
        let d = 16;
        let nu = 2.0;
        let x = unit_vector(&mut rng, d);
        let y = unit_vector(&mut rng, d);
        let exact = gaussian_kernel(nu, &x, &y);
        let mut acc = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let m = SorfMap::new(d, 64, nu, &mut rng);
            acc += m.approx_kernel(&x, &y);
        }
        let est = acc / reps as f64;
        assert!((est - exact).abs() < 0.04, "{est} vs {exact}");
    }

    #[test]
    fn nonpow2_input_is_padded() {
        let mut rng = Rng::seeded(65);
        let m = SorfMap::new(7, 16, 1.0, &mut rng);
        assert_eq!(m.input_dim(), 7);
        let u = unit_vector(&mut rng, 7);
        let phi = m.map(&u);
        assert_eq!(phi.len(), 32);
    }
}
