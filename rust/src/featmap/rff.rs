//! Classic and Orthogonal Random Fourier Features for the Gaussian kernel
//! `K(x,y) = exp(-ν‖x−y‖²/2)` (paper eq. 16–18).
//!
//! Sampling note (paper Appendix B): the frequency rows are drawn
//! `w ~ N(0, ν·I)` so that `E[cos(wᵀ(x−y))] = exp(-ν‖x−y‖²/2)`.
//! (Eq. 17 of the paper writes `N(0, I/ν)`; the appendix form is the one
//! consistent with eq. 18 and is what we implement — a ν→1/ν swap there is
//! a known typo.)

use super::FeatureMap;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Classic RFF map: `φ(u) = √(1/D) [cos(Wu) ‖ sin(Wu)]` with
/// `W ∈ ℝ^{D×d}`, rows i.i.d. `N(0, ν·I)`. Output dimension is `2D`.
#[derive(Clone, Debug)]
pub struct RffMap {
    w: Matrix,
    nu: f32,
    inv_sqrt_d: f32,
}

impl RffMap {
    /// `num_freqs` = D (number of frequency vectors; output dim is 2D).
    pub fn new(input_dim: usize, num_freqs: usize, nu: f32, rng: &mut Rng) -> Self {
        assert!(num_freqs > 0 && input_dim > 0);
        assert!(nu > 0.0, "RffMap: ν must be positive");
        let w = Matrix::randn_scaled(rng, num_freqs, input_dim, nu.sqrt());
        Self { w, nu, inv_sqrt_d: 1.0 / (num_freqs as f32).sqrt() }
    }

    /// Build from an explicit frequency matrix (used by [`OrfMap`]).
    fn from_freqs(w: Matrix, nu: f32) -> Self {
        let d = w.rows();
        Self { w, nu, inv_sqrt_d: 1.0 / (d as f32).sqrt() }
    }

    pub fn nu(&self) -> f32 {
        self.nu
    }

    pub fn num_freqs(&self) -> usize {
        self.w.rows()
    }
}

impl FeatureMap for RffMap {
    fn output_dim(&self) -> usize {
        2 * self.w.rows()
    }

    fn input_dim(&self) -> usize {
        self.w.cols()
    }

    fn map_into(&self, u: &[f32], out: &mut [f32]) {
        let d_f = self.w.rows();
        debug_assert_eq!(out.len(), 2 * d_f);
        debug_assert_eq!(u.len(), self.w.cols());
        // Wu then cos/sin, scaled by 1/√D.
        for i in 0..d_f {
            let proj = crate::linalg::dot(self.w.row(i), u);
            let (s, c) = proj.sin_cos();
            out[i] = c * self.inv_sqrt_d;
            out[d_f + i] = s * self.inv_sqrt_d;
        }
    }

    /// Batch override: the whole batch's projections come from one
    /// gemm `U · Wᵀ` — [`Matrix::matmul_nt`], which dispatches to the
    /// [`crate::linalg::simd`] microkernel tier resolved at startup —
    /// amortizing W traffic across rows, then a single pointwise
    /// `sin_cos` sweep writes the cos‖sin halves.
    fn map_batch_into(&self, u: &Matrix, out: &mut Matrix) {
        let d_f = self.w.rows();
        assert_eq!(u.cols(), self.w.cols(), "map_batch_into: input dim");
        assert_eq!(out.cols(), 2 * d_f, "map_batch_into: output dim");
        assert_eq!(u.rows(), out.rows(), "map_batch_into: batch mismatch");
        let proj = u.matmul_nt(&self.w);
        for i in 0..u.rows() {
            let prow = proj.row(i);
            let orow = out.row_mut(i);
            for j in 0..d_f {
                let (s, c) = prow[j].sin_cos();
                orow[j] = c * self.inv_sqrt_d;
                orow[d_f + j] = s * self.inv_sqrt_d;
            }
        }
    }

    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        super::gaussian_kernel(self.nu, x, y)
    }
}

/// Orthogonal Random Features (Yu et al., NeurIPS 2016): the frequency
/// matrix is built from orthogonalized Gaussian blocks with chi-distributed
/// row norms — an unbiased Gaussian-kernel estimator with strictly lower
/// variance than i.i.d. RFF at the same D.
#[derive(Clone, Debug)]
pub struct OrfMap {
    inner: RffMap,
}

impl OrfMap {
    pub fn new(input_dim: usize, num_freqs: usize, nu: f32, rng: &mut Rng) -> Self {
        assert!(num_freqs > 0 && input_dim > 0);
        assert!(nu > 0.0, "OrfMap: ν must be positive");
        let mut w = Matrix::zeros(num_freqs, input_dim);
        let mut row0 = 0;
        while row0 < num_freqs {
            let block = (num_freqs - row0).min(input_dim);
            // Orthonormal directions…
            let mut q = Matrix::randn(rng, block, input_dim);
            q.orthonormalize_rows(rng);
            // …rescaled to chi(d)-distributed norms (matching the norm
            // distribution of Gaussian rows), then by √ν for the kernel.
            for b in 0..block {
                let norm: f32 = {
                    let mut s = 0.0f32;
                    for _ in 0..input_dim {
                        let g = rng.gaussian_f32();
                        s += g * g;
                    }
                    s.sqrt()
                };
                let scale = norm * nu.sqrt();
                let src = q.row(b);
                let dst = w.row_mut(row0 + b);
                for (d, s_) in dst.iter_mut().zip(src.iter()) {
                    *d = s_ * scale;
                }
            }
            row0 += block;
        }
        Self { inner: RffMap::from_freqs(w, nu) }
    }

    pub fn nu(&self) -> f32 {
        self.inner.nu
    }
}

impl FeatureMap for OrfMap {
    fn output_dim(&self) -> usize {
        self.inner.output_dim()
    }

    fn input_dim(&self) -> usize {
        self.inner.input_dim()
    }

    fn map_into(&self, u: &[f32], out: &mut [f32]) {
        self.inner.map_into(u, out)
    }

    fn map_batch_into(&self, u: &Matrix, out: &mut Matrix) {
        self.inner.map_batch_into(u, out)
    }

    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        self.inner.exact_kernel(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::kernel_mse;
    use crate::linalg::unit_vector;

    fn pairs(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..n)
            .map(|_| (unit_vector(rng, d), unit_vector(rng, d)))
            .collect()
    }

    #[test]
    fn rff_is_unbiased_for_gaussian_kernel() {
        // Average approx over many independent maps → exact kernel.
        let mut rng = Rng::seeded(51);
        let d = 8;
        let x = unit_vector(&mut rng, d);
        let y = unit_vector(&mut rng, d);
        let nu = 2.0;
        let exact = crate::featmap::gaussian_kernel(nu, &x, &y);
        let mut acc = 0.0;
        let reps = 300;
        for _ in 0..reps {
            let m = RffMap::new(d, 32, nu, &mut rng);
            acc += m.approx_kernel(&x, &y);
        }
        let est = acc / reps as f64;
        assert!(
            (est - exact).abs() < 0.02,
            "bias too large: {est} vs {exact}"
        );
    }

    #[test]
    fn rff_mse_decreases_with_d() {
        let mut rng = Rng::seeded(52);
        let d = 16;
        let ps = pairs(&mut rng, 200, d);
        let small = RffMap::new(d, 16, 1.0, &mut rng);
        let large = RffMap::new(d, 1024, 1.0, &mut rng);
        let mse_small = kernel_mse(&small, &ps);
        let mse_large = kernel_mse(&large, &ps);
        assert!(
            mse_large < mse_small / 4.0,
            "D=16: {mse_small:.2e}, D=1024: {mse_large:.2e}"
        );
    }

    #[test]
    fn orf_not_worse_than_rff() {
        // ORF has provably lower variance; check empirically with margin.
        let mut rng = Rng::seeded(53);
        let d = 32;
        let ps = pairs(&mut rng, 300, d);
        let mut rff_mse = 0.0;
        let mut orf_mse = 0.0;
        let reps = 8;
        for _ in 0..reps {
            let rffm = RffMap::new(d, 64, 2.0, &mut rng);
            let orfm = OrfMap::new(d, 64, 2.0, &mut rng);
            rff_mse += kernel_mse(&rffm, &ps);
            orf_mse += kernel_mse(&orfm, &ps);
        }
        assert!(
            orf_mse < rff_mse * 1.05,
            "orf {orf_mse:.3e} vs rff {rff_mse:.3e}"
        );
    }

    #[test]
    fn map_output_in_unit_ball() {
        // ‖φ(u)‖² = (1/D)Σ(cos²+sin²) = 1 exactly.
        let mut rng = Rng::seeded(54);
        let m = RffMap::new(10, 40, 1.5, &mut rng);
        let u = unit_vector(&mut rng, 10);
        let phi = m.map(&u);
        assert_eq!(phi.len(), 80);
        let norm2: f32 = phi.iter().map(|v| v * v).sum();
        assert!((norm2 - 1.0).abs() < 1e-4, "‖φ‖² = {norm2}");
    }

    #[test]
    fn orf_blocks_cover_d_gt_input_dim() {
        let mut rng = Rng::seeded(55);
        let m = OrfMap::new(8, 20, 1.0, &mut rng); // 20 > 8 → 3 blocks
        assert_eq!(m.output_dim(), 40);
        let u = unit_vector(&mut rng, 8);
        let phi = m.map(&u);
        let norm2: f32 = phi.iter().map(|v| v * v).sum();
        assert!((norm2 - 1.0).abs() < 1e-4);
    }
}
