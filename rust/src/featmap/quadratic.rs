//! Explicit quadratic-kernel linearization (Blanc & Rendle 2018, paper
//! eq. 15): `K_quad(h, c) = α·(hᵀc)² + β` with the feature map
//! `φ(z) = [√α·(z ⊗ z), √β]`, so `φ(x)ᵀφ(y) = α(xᵀy)² + β` **exactly**
//! (zero approximation error with respect to its own kernel — the bias is
//! in how poorly the quadratic kernel tracks `e^{o}`; paper §3.1).
//!
//! `D = d² + 1`, which is what makes Quadratic-softmax cost `O(d² log n)`
//! per sample and motivates RF-softmax.

use super::FeatureMap;
use crate::linalg::dot;

#[derive(Clone, Debug)]
pub struct QuadraticMap {
    input_dim: usize,
    alpha: f32,
    beta: f32,
}

impl QuadraticMap {
    /// The paper's baseline uses α = 100, β = 1.
    pub fn new(input_dim: usize, alpha: f32, beta: f32) -> Self {
        assert!(input_dim > 0);
        assert!(alpha >= 0.0 && beta >= 0.0, "QuadraticMap: α, β must be ≥ 0");
        Self { input_dim, alpha, beta }
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    /// Least-squares fit of (α, β) minimizing
    /// `Σ (α·(xᵀy)² + β − target(x,y))²` over sample pairs — the
    /// "optimal MSE" variant reported in paper Table 1.
    pub fn fit(
        input_dim: usize,
        pairs: &[(Vec<f32>, Vec<f32>)],
        target: impl Fn(&[f32], &[f32]) -> f64,
    ) -> Self {
        // Normal equations for the 2-parameter linear model y = αu + β,
        // u := (xᵀy)².
        let mut suu = 0.0f64;
        let mut su = 0.0f64;
        let mut sy = 0.0f64;
        let mut suy = 0.0f64;
        let n = pairs.len() as f64;
        for (x, y) in pairs {
            let u = (dot(x, y) as f64).powi(2);
            let t = target(x, y);
            suu += u * u;
            su += u;
            sy += t;
            suy += u * t;
        }
        let det = suu * n - su * su;
        let (alpha, beta) = if det.abs() < 1e-12 {
            (0.0, sy / n)
        } else {
            let a = (suy * n - su * sy) / det;
            let b = (suu * sy - su * suy) / det;
            (a, b)
        };
        // The sampling tree needs a nonnegative kernel; clamp.
        Self {
            input_dim,
            alpha: alpha.max(0.0) as f32,
            beta: beta.max(0.0) as f32,
        }
    }
}

impl FeatureMap for QuadraticMap {
    fn output_dim(&self) -> usize {
        self.input_dim * self.input_dim + 1
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn map_into(&self, u: &[f32], out: &mut [f32]) {
        let d = self.input_dim;
        debug_assert_eq!(u.len(), d);
        debug_assert_eq!(out.len(), d * d + 1);
        let sa = self.alpha.sqrt();
        for i in 0..d {
            let ui = u[i] * sa;
            let row = &mut out[i * d..(i + 1) * d];
            for (o, &uj) in row.iter_mut().zip(u.iter()) {
                *o = ui * uj;
            }
        }
        out[d * d] = self.beta.sqrt();
    }

    /// Batch override: hoists the √α/√β constants out of the row loop and
    /// writes each row's outer product in one streaming pass.
    fn map_batch_into(
        &self,
        u: &crate::linalg::Matrix,
        out: &mut crate::linalg::Matrix,
    ) {
        let d = self.input_dim;
        assert_eq!(u.cols(), d, "map_batch_into: input dim");
        assert_eq!(out.cols(), d * d + 1, "map_batch_into: output dim");
        assert_eq!(u.rows(), out.rows(), "map_batch_into: batch mismatch");
        let sa = self.alpha.sqrt();
        let sb = self.beta.sqrt();
        for r in 0..u.rows() {
            let urow = u.row(r);
            let orow = out.row_mut(r);
            for i in 0..d {
                let ui = urow[i] * sa;
                let dst = &mut orow[i * d..(i + 1) * d];
                for (o, &uj) in dst.iter_mut().zip(urow.iter()) {
                    *o = ui * uj;
                }
            }
            orow[d * d] = sb;
        }
    }

    fn exact_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        let s = dot(x, y) as f64;
        self.alpha as f64 * s * s + self.beta as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::exp_kernel;
    use crate::linalg::unit_vector;
    use crate::rng::Rng;

    #[test]
    fn linearization_is_exact() {
        let mut rng = Rng::seeded(71);
        let m = QuadraticMap::new(12, 100.0, 1.0);
        for _ in 0..20 {
            let x = unit_vector(&mut rng, 12);
            let y = unit_vector(&mut rng, 12);
            let exact = m.exact_kernel(&x, &y);
            let approx = m.approx_kernel(&x, &y);
            assert!(
                (exact - approx).abs() < 1e-3 * exact.abs().max(1.0),
                "{exact} vs {approx}"
            );
        }
    }

    #[test]
    fn output_dim_is_d_squared_plus_one() {
        let m = QuadraticMap::new(16, 100.0, 1.0);
        assert_eq!(m.output_dim(), 257);
    }

    #[test]
    fn fit_beats_fixed_alpha_for_exp_target() {
        let mut rng = Rng::seeded(72);
        let d = 16;
        let tau = 1.0f32;
        let pairs: Vec<_> = (0..500)
            .map(|_| (unit_vector(&mut rng, d), unit_vector(&mut rng, d)))
            .collect();
        let target = |x: &[f32], y: &[f32]| exp_kernel(tau, x, y);
        let fitted = QuadraticMap::fit(d, &pairs, target);
        let fixed = QuadraticMap::new(d, 100.0, 1.0);
        let mse = |m: &QuadraticMap| {
            pairs
                .iter()
                .map(|(x, y)| {
                    let e = target(x, y) - m.exact_kernel(x, y);
                    e * e
                })
                .sum::<f64>()
                / pairs.len() as f64
        };
        assert!(
            mse(&fitted) <= mse(&fixed),
            "fitted {:.3e} vs fixed {:.3e}",
            mse(&fitted),
            mse(&fixed)
        );
    }

    #[test]
    fn fitted_params_nonnegative() {
        let mut rng = Rng::seeded(73);
        let d = 8;
        let pairs: Vec<_> = (0..100)
            .map(|_| (unit_vector(&mut rng, d), unit_vector(&mut rng, d)))
            .collect();
        let m = QuadraticMap::fit(d, &pairs, |x, y| exp_kernel(1.0, x, y));
        assert!(m.alpha() >= 0.0 && m.beta() >= 0.0);
    }
}
